"""SimPoint-style phase fingerprinting + clustering (gem5 §1.3, §2.7).

SimPoint's bargain: program execution is phasic, so cluster fixed-size
intervals by their basic-block vectors (BBVs), simulate one
representative per cluster in detail, and reconstruct the whole run as
the weighted sum.  Our traces have no basic blocks, but they have the
exact analogue of a BBV — the **op-mix vector** of a window of steps:
how many compute ops and of which collective kinds, how many flops,
how many payload bytes on ICI vs DCN.  Two windows with the same op-mix
cost the same under any timing model, so clustering op-mix vectors
finds the phases that matter for *timing* (a flash-crowd burst of
contending collectives looks nothing like a calm step, and lands in its
own cluster).

Pipeline (all dependency-free, deterministic under a seed):

* :func:`fingerprint_trace` — slice a chained multi-step trace (or any
  op stream) into fixed windows, one feature vector per window.
* :func:`cluster_fingerprint` — seeded k-means++ over max-normalized
  vectors with BIC-based choice of k (the SimPoint recipe: pick the
  smallest k whose BIC is within ``bic_threshold`` of the best).
* :func:`simpoint_plan` — representatives + weights as a
  :class:`~repro.sim.sampling.SimPointPlan` that plugs into
  ``SampledSimulation`` next to the fixed-stride ``SamplePlan``.
* :func:`record_op_stream` — run a dynamic workload once at atomic
  fidelity and return its injected op stream as a static trace, so
  ServeSim/TrainSim/FleetSim runs can be fingerprinted the same way.

``bursty_trace`` builds the seeded non-steady-state reference workload
(calm steps punctuated by a flash-crowd-like burst phase whose parallel
collectives contend for shared links) used by the ``simpoint`` CI tier
and ``benchmarks/simpoint_sweep.py``.
"""

from __future__ import annotations

import random
from dataclasses import dataclass, field, replace
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.desim.trace import COLLECTIVE_OPS, HloTrace, TraceOp

__all__ = [
    "FEATURE_NAMES", "Fingerprint", "fingerprint_trace",
    "cluster_fingerprint", "kmeans", "simpoint_plan",
    "record_op_stream", "chain_steps", "bursty_trace",
]

# Fixed feature ordering — NEVER derived from dict iteration, so the
# vectors (and everything clustered from them) are identical across
# interpreters regardless of PYTHONHASHSEED.
FEATURE_NAMES: Tuple[str, ...] = (
    ("n_compute", "flops", "hbm_bytes")
    + tuple(f"n_{k}" for k in COLLECTIVE_OPS)
    + ("ici_coll_bytes", "dcn_coll_bytes", "n_overlap")
)

_KIND_SLOT = {k: 3 + i for i, k in enumerate(COLLECTIVE_OPS)}


def op_mix_vector(ops: Sequence[TraceOp]) -> List[float]:
    """The BBV analogue: op-mix feature vector of one window of ops."""
    v = [0.0] * len(FEATURE_NAMES)
    for op in ops:
        if op.kind == "compute":
            v[0] += 1.0
            v[1] += op.flops
            v[2] += op.bytes
        else:
            slot = _KIND_SLOT.get(op.kind)
            if slot is not None:
                v[slot] += 1.0
            if op.scope == "dcn":
                v[-2] += op.coll_bytes
            else:
                v[-3] += op.coll_bytes
        if op.overlap:
            v[-1] += 1.0
    return v


@dataclass
class Fingerprint:
    """Per-window op-mix vectors of a sliced trace.

    ``window``  : steps per window (the SimPoint interval size).
    ``step_ops``: ops per step (uniform across steps — the slicing
                  contract ``SampledSimulation`` also relies on).
    ``vectors`` : one row per window, columns = :data:`FEATURE_NAMES`;
                  the final window may cover fewer steps (remainder).
    """

    window: int
    num_steps: int
    step_ops: int
    vectors: List[List[float]] = field(default_factory=list)

    @property
    def num_windows(self) -> int:
        return len(self.vectors)

    def window_steps(self, widx: int) -> int:
        """Steps covered by window ``widx`` (the last may be partial)."""
        full = self.num_steps - widx * self.window
        return max(0, min(self.window, full))


def fingerprint_trace(trace: HloTrace, num_steps: Optional[int] = None,
                      window: int = 1) -> Fingerprint:
    """Slice a chained multi-step trace into ``window``-step windows.

    ``num_steps`` defaults to ``trace.meta["steps"]`` (set by
    ``repeat_trace``/``chain_steps``); the trace must divide evenly
    into that many steps.  A remainder of steps smaller than ``window``
    becomes a final partial window.
    """
    if window < 1:
        raise ValueError("window must be >= 1 step")
    if num_steps is None:
        num_steps = int(trace.meta.get("steps", 0))
    if num_steps < 1:
        raise ValueError(
            "num_steps must be >= 1 (pass it explicitly, or fingerprint "
            "a trace built by repeat_trace/chain_steps which stamp "
            "meta['steps'])")
    n = len(trace.ops)
    if n % num_steps:
        raise ValueError(
            f"trace has {n} ops, not divisible into {num_steps} "
            "uniform steps")
    step_ops = n // num_steps
    fp = Fingerprint(window=window, num_steps=num_steps,
                     step_ops=step_ops)
    for lo in range(0, num_steps, window):
        hi = min(lo + window, num_steps)
        fp.vectors.append(
            op_mix_vector(trace.ops[lo * step_ops:hi * step_ops]))
    return fp


# ---------------------------------------------------------------------------
# dependency-free k-means (seeded, deterministic)
# ---------------------------------------------------------------------------

def _normalize(vectors: List[List[float]]) -> List[List[float]]:
    """Per-dimension max normalization onto [0, 1] — flop counts are
    ~1e12 and op counts ~1e1; unnormalized distance would only see
    flops."""
    if not vectors:
        return []
    dims = len(vectors[0])
    mx = [max(abs(v[d]) for v in vectors) or 1.0 for d in range(dims)]
    return [[v[d] / mx[d] for d in range(dims)] for v in vectors]


def _dist2(a: Sequence[float], b: Sequence[float]) -> float:
    return sum((x - y) * (x - y) for x, y in zip(a, b))


def kmeans(vectors: List[List[float]], k: int, seed: int = 0,
           iters: int = 50) -> Tuple[List[int], List[List[float]]]:
    """Seeded k-means++ (Lloyd iterations, deterministic tie-breaks).

    Returns ``(labels, centroids)``.  All arithmetic is plain Python
    floats over stable orderings, so the same (vectors, k, seed) gives
    the same clustering in any interpreter.
    """
    n = len(vectors)
    if not 1 <= k <= n:
        raise ValueError(f"need 1 <= k <= {n} windows, got k={k}")
    rng = random.Random(seed)
    # k-means++ seeding: first centroid uniform, rest D^2-weighted
    centroids = [list(vectors[rng.randrange(n)])]
    d2 = [_dist2(v, centroids[0]) for v in vectors]
    for _ in range(1, k):
        total = sum(d2)
        if total <= 0.0:        # all points coincide with a centroid
            centroids.append(list(centroids[0]))
            continue
        r = rng.random() * total
        acc = 0.0
        pick = n - 1
        for i, w in enumerate(d2):
            acc += w
            if acc >= r:
                pick = i
                break
        centroids.append(list(vectors[pick]))
        d2 = [min(a, _dist2(v, centroids[-1]))
              for a, v in zip(d2, vectors)]
    labels = [0] * n
    for it in range(iters):
        # assign (ties break to the lowest cluster id)
        new_labels = []
        for v in vectors:
            best, best_d = 0, _dist2(v, centroids[0])
            for c in range(1, k):
                d = _dist2(v, centroids[c])
                if d < best_d:
                    best, best_d = c, d
            new_labels.append(best)
        if new_labels == labels and it > 0:
            break
        labels = new_labels
        # update (empty clusters keep their centroid)
        for c in range(k):
            members = [vectors[i] for i in range(n) if labels[i] == c]
            if members:
                dims = len(members[0])
                centroids[c] = [
                    sum(m[d] for m in members) / len(members)
                    for d in range(dims)]
    return labels, centroids


def _bic(vectors: List[List[float]], labels: List[int],
         centroids: List[List[float]]) -> float:
    """Spherical-Gaussian BIC (the x-means/SimPoint model-selection
    score): log-likelihood under per-cluster spherical Gaussians minus
    the parameter-count penalty."""
    import math
    n = len(vectors)
    k = len(centroids)
    d = len(vectors[0])
    rss = sum(_dist2(v, centroids[labels[i]])
              for i, v in enumerate(vectors))
    sigma2 = max(rss / max(n - k, 1), 1e-12)
    ll = 0.0
    for c in range(k):
        nc = sum(1 for l in labels if l == c)
        if nc <= 0:
            continue
        ll += (nc * math.log(nc / n)
               - nc * d / 2.0 * math.log(2.0 * math.pi * sigma2))
    ll -= rss / (2.0 * sigma2)
    params = k * (d + 1)
    return ll - params / 2.0 * math.log(n)


def cluster_fingerprint(fp: Fingerprint, max_k: int = 8, seed: int = 0,
                        bic_threshold: float = 0.9
                        ) -> Tuple[List[int], int]:
    """Cluster windows; choose k by the SimPoint BIC rule.

    Runs k-means for k = 1..min(max_k, windows), scores each clustering
    with BIC, and picks the *smallest* k whose min-max-normalized BIC
    reaches ``bic_threshold`` of the best — SimPoint's bias toward few
    representatives.  Returns ``(labels, k)``.
    """
    norm = _normalize(fp.vectors)
    n = len(norm)
    if n == 0:
        raise ValueError("empty fingerprint")
    kmax = max(1, min(max_k, n))
    runs: List[Tuple[List[int], float]] = []
    for k in range(1, kmax + 1):
        labels, cents = kmeans(norm, k, seed=seed)
        runs.append((labels, _bic(norm, labels, cents)))
    scores = [b for _, b in runs]
    lo, hi = min(scores), max(scores)
    span = (hi - lo) or 1.0
    for k0, (labels, b) in enumerate(runs):
        if (b - lo) / span >= bic_threshold:
            return labels, k0 + 1
    return runs[-1][0], kmax


def simpoint_plan(trace: HloTrace, num_steps: Optional[int] = None,
                  window: int = 1, max_k: int = 8, seed: int = 0,
                  bic_threshold: float = 0.9):
    """fingerprint → cluster → :class:`~repro.sim.sampling.SimPointPlan`.

    Representative of a cluster = the window closest to its centroid in
    normalized feature space (earliest window on ties); weight = the
    cluster's share of all windows.
    """
    from repro.sim.sampling import SimPointPlan
    fp = fingerprint_trace(trace, num_steps=num_steps, window=window)
    labels, k = cluster_fingerprint(fp, max_k=max_k, seed=seed,
                                    bic_threshold=bic_threshold)
    norm = _normalize(fp.vectors)
    n = len(norm)
    reps: Dict[int, int] = {}
    sizes: Dict[int, int] = {}
    for c in range(k):
        members = [i for i in range(n) if labels[i] == c]
        if not members:
            continue
        dims = len(norm[0])
        cent = [sum(norm[i][d] for i in members) / len(members)
                for d in range(dims)]
        best = min(members, key=lambda i: (_dist2(norm[i], cent), i))
        reps[c] = best
        sizes[c] = len(members)
    order = sorted(reps.values())
    weight_of = {reps[c]: sizes[c] / n for c in reps}
    return SimPointPlan(window=fp.window,
                        representatives=order,
                        weights=[weight_of[w] for w in order],
                        labels=list(labels))


# ---------------------------------------------------------------------------
# op-stream recording (dynamic workloads) + reference workloads
# ---------------------------------------------------------------------------

def record_op_stream(board, workload, timing: str = "atomic") -> HloTrace:
    """Run a dynamic workload once (cheaply, at ``timing`` fidelity) and
    return the op stream it injected as a static, replayable trace —
    the elastic-trace record pass that makes ServeSim/TrainSim/FleetSim
    runs fingerprintable like any static trace.

    The stream is *not* stamped with ``meta["steps"]``: injected ops
    have no step structure, so fingerprint it with an explicit op-count
    window via :func:`fingerprint_ops`-style slicing (pass
    ``num_steps=len(ops)`` and a step-sized ``window``), or replay it
    as a whole.
    """
    from repro.sim.simulator import Simulator
    sim = Simulator(board, workload, timing=timing)
    for _ in sim.run():
        pass
    src = sim._ex._trace
    rec = HloTrace(name=f"recorded:{src.name}",
                   ops=[replace(op) for op in src.ops],
                   meta={"recorded": 1.0})
    return rec


def chain_steps(steps: List[HloTrace], name: str = "chained") -> HloTrace:
    """Chain *heterogeneous* per-step traces into one multi-step trace
    (``repeat_trace`` for non-steady-state workloads): each step's root
    ops depend on the previous step's sink ops, and ``meta["steps"]``
    is stamped so ``SampledSimulation``/``fingerprint_trace`` recognize
    the step structure.  Every step must have the same op count (the
    uniform-step contract window accounting relies on)."""
    if not steps:
        raise ValueError("need at least one step")
    n = len(steps[0].ops)
    if any(len(s.ops) != n for s in steps):
        raise ValueError("all steps must have the same op count "
                         f"(got {sorted({len(s.ops) for s in steps})})")
    out = HloTrace(name, meta=dict(steps[0].meta, steps=len(steps)))
    prev_sinks: Tuple[int, ...] = ()
    for rep, step in enumerate(steps):
        off = rep * n
        has_dependent = [False] * n
        for op in step.ops:
            for d in op.deps:
                has_dependent[d] = True
        for idx, op in enumerate(step.ops):
            deps = tuple(d + off for d in op.deps)
            if not deps and rep > 0:
                deps = prev_sinks
            out.ops.append(replace(
                op, deps=deps,
                name=f"step{rep}/{op.name}" if op.name else ""))
        prev_sinks = tuple(off + i for i in range(n)
                           if not has_dependent[i])
    return out


def bursty_trace(num_steps: int = 100, burst_start: int = 55,
                 burst_len: int = 20, fan: int = 4,
                 calm_bytes: float = 2e6, burst_bytes: float = 240e6,
                 layer_flops: float = 4e12, layer_bytes: float = 1.2e9,
                 seed: int = 0, name: str = "bursty") -> HloTrace:
    """The seeded non-steady-state reference workload: a flash-crowd-
    like phase schedule over a static trace.

    Every step has the identical op *count* (1 compute + ``fan``
    collectives — the uniform-step contract), but the burst phase's
    ``fan`` collectives are large and **parallel** (all depend only on
    the step's compute op, whole-pod region), so under
    ``DetailedTiming`` they contend for the same ICI links and
    serialize ~``fan``-fold, while ``AtomicTiming`` overlaps them at
    the contention-free cost.  Calm steps carry tiny payloads either
    way.  That detailed-vs-atomic gap exists *only* inside the burst —
    exactly the phase a fixed-stride sample plan misses unless a window
    happens to land there, and the phase a SimPoint fingerprint finds
    from the op-mix (burst windows have ~100x the ici_coll_bytes).

    ``seed`` jitters per-step payload bytes ±10% so the trace is
    non-degenerate but fully reproducible.
    """
    if not (0 <= burst_start and burst_start + burst_len <= num_steps):
        raise ValueError("burst must lie inside [0, num_steps)")
    rng = random.Random(seed)
    steps: List[HloTrace] = []
    for s in range(num_steps):
        burst = burst_start <= s < burst_start + burst_len
        base = burst_bytes if burst else calm_bytes
        t = HloTrace(f"{name}/step{s}")
        t.ops.append(TraceOp(kind="compute", flops=layer_flops,
                             bytes=layer_bytes, name="fwdbwd"))
        for f in range(fan):
            jitter = 1.0 + 0.1 * (2.0 * rng.random() - 1.0)
            t.ops.append(TraceOp(
                kind="all-reduce", coll_bytes=base * jitter,
                participants=0, deps=(0,), scope="ici",
                name=f"grad{f}"))
        steps.append(t)
    return chain_steps(steps, name=name)
