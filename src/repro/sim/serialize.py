"""gem5-style drain-then-serialize checkpointing (paper §2.7).

gem5 checkpoints by *draining* the system (every SimObject finishes its
in-flight transactions) and then serializing the SimObject tree to a
checkpoint directory; restoring may target a *differently configured*
system — the canonical workflow is "checkpoint after OS boot once,
restore onto every cache hierarchy you want to sweep".  g5x reproduces
that for trace replay:

* ``checkpoint_executor`` — a drained :class:`TraceExecutor` becomes a
  versioned, plain-JSON dict: the machine description (``SimObject.
  serialize``, gem5's config.ini analogue), the executor config, the
  elastic trace, and the drained run state (completed-op ticks, the
  deferred frontier, partial DCN rendezvous, per-link occupancy, the
  full stats-tree accumulator state, per-pod queue tick snapshots).
* ``restore_executor`` — rebuilds a ready-to-``advance`` executor from
  a checkpoint, optionally onto a **re-parameterized machine** (sweep
  HBM/ICI/DCN speeds from one checkpoint; pod count must match).
  Restored on the same machine, the resumed run's final tick and stats
  tree are identical to a run that never paused (test-enforced in
  ``tests/test_sim_checkpoint.py``).

The file format is one JSON document, ``version``-stamped so future
layouts can migrate old checkpoints instead of mis-reading them.
"""

from __future__ import annotations

import json
import os
from typing import Any, Dict, Optional

from repro.core.desim.executor import TraceExecutor
from repro.core.desim.machine import ClusterModel
from repro.core.desim.trace import HloTrace

CHECKPOINT_VERSION = 2
#: versions this reader still restores.  v2 is additive over v1 (new
#: optional ``parallel_protocol`` header key recording which
#: coordinator/worker wire protocol wrote the document — checkpoints
#: themselves stay serial-format and worker-count-agnostic), so v1
#: documents restore unchanged.
SUPPORTED_CHECKPOINT_VERSIONS = (1, 2)
CHECKPOINT_FORMAT = "repro.sim.checkpoint"

# optional top-level key carrying a dynamic workload's state (pending
# arrivals, scheduler state, percentile accumulators — see
# ``repro.sim.workloads``).  Static-trace checkpoints omit it; the key
# is additive, so the format version is unchanged.
WORKLOAD_KEY = "workload"

# optional sibling key naming the workload class the state belongs to
# (``ServeSim``, ``TrainSim``, ...): restoring a TrainSim checkpoint
# into a rebuilt ServeSim would otherwise fail deep inside
# ``load_state_dict`` with an opaque KeyError.  Additive, like
# WORKLOAD_KEY (older checkpoints without it restore unchecked).
WORKLOAD_KIND_KEY = "workload_kind"


class CheckpointError(RuntimeError):
    pass


def validate_workers(workers: Optional[int]) -> int:
    """``None`` means "serial" (1); anything else must be an int >= 1.

    ``workers=0`` used to be silently coerced to 1 via ``int(x or 1)``
    — a config typo that *looked* parallel but ran serial.  Reject it
    the way :class:`~repro.core.events.EventQueue` rejects negative
    ticks: loudly, at the call site.
    """
    if workers is None:
        return 1
    w = int(workers)
    if w < 1:
        raise ValueError(
            f"cannot build an executor with workers={workers!r} "
            "(worker count is a process count, >= 1; omit it or pass "
            "None for the serial engine)")
    return w


# ---------------------------------------------------------------------------
# machine description
# ---------------------------------------------------------------------------

def machine_to_dict(machine: ClusterModel) -> Dict[str, Any]:
    return machine.serialize()


def machine_from_dict(d: Dict[str, Any]) -> ClusterModel:
    """Rebuild an instantiated ClusterModel from ``machine_to_dict``.

    Construction is shape-specific (a ClusterModel always owns
    pod/chip/ici/dcn children), parameter application is generic
    (``SimObject.load_serialized``).
    """
    m = ClusterModel(d.get("name", "cluster"))
    m.load_serialized(d, strict=False)
    m.instantiate()
    return m


# ---------------------------------------------------------------------------
# checkpoint build / save / load / restore
# ---------------------------------------------------------------------------

def checkpoint_executor(ex: TraceExecutor) -> Dict[str, Any]:
    """Serialize a drained executor (call ``ex.drain()`` first)."""
    from repro.core.desim.parallel import PARALLEL_PROTOCOL
    state = ex.snapshot()          # raises unless drained
    return {
        "format": CHECKPOINT_FORMAT,
        "version": CHECKPOINT_VERSION,
        "parallel_protocol": PARALLEL_PROTOCOL,
        "tick": state["tick"],
        "machine": machine_to_dict(ex.machine),
        "executor": {
            "algorithm": ex.algorithm,
            "straggler_slowdowns": list(ex.slow),
            "timing": ex.timing.name,
            "contention": ex.contention,      # legacy (== timing.detailed)
            "record_timeline": ex.record_timeline,
            "record_stats": ex.record_stats,
        },
        "trace": json.loads(ex._trace.to_json()),
        "state": state,
    }


def save_checkpoint(ckpt: Dict[str, Any], path: str) -> str:
    _check_header(ckpt)
    d = os.path.dirname(path)
    if d:
        os.makedirs(d, exist_ok=True)
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(ckpt, f)
    os.replace(tmp, path)
    return path


def load_checkpoint(path: str) -> Dict[str, Any]:
    with open(path) as f:
        ckpt = json.load(f)
    _check_header(ckpt)
    return ckpt


def _check_header(ckpt: Dict[str, Any]) -> None:
    if ckpt.get("format") != CHECKPOINT_FORMAT:
        raise CheckpointError(
            f"not a {CHECKPOINT_FORMAT} document "
            f"(format={ckpt.get('format')!r})")
    if ckpt.get("version") not in SUPPORTED_CHECKPOINT_VERSIONS:
        raise CheckpointError(
            f"checkpoint version {ckpt.get('version')!r} not in "
            f"{SUPPORTED_CHECKPOINT_VERSIONS} (no migration registered)")


def trace_from_checkpoint(ckpt: Dict[str, Any]) -> HloTrace:
    return HloTrace.from_json(json.dumps(ckpt["trace"]))


def restore_executor(ckpt: Dict[str, Any],
                     machine: Optional[ClusterModel] = None,
                     **overrides) -> TraceExecutor:
    """A ready-to-``advance`` executor from a checkpoint dict.

    ``machine``: restore onto this (instantiated) machine instead of
    rebuilding the checkpointed one — the DSE re-parameterization hook.
    ``overrides``: TraceExecutor kwargs overriding the checkpointed
    config (e.g. ``record_stats=True``, or ``timing="detailed"`` — the
    gem5 ``switch_cpus`` move: a checkpoint taken under one timing
    model restores under another).  ``workers=N`` (N>1) restores into
    the multiprocess :class:`~repro.core.desim.parallel.ParallelEngine`
    — checkpoints are worker-count-agnostic, so a snapshot taken under
    any worker count restores under any other.
    """
    _check_header(ckpt)
    trace = trace_from_checkpoint(ckpt)
    if machine is None:
        machine = machine_from_dict(ckpt["machine"])
    cfg = dict(ckpt["executor"])
    # a None override must not shadow the checkpointed timing model
    cfg.update({k: v for k, v in overrides.items()
                if not (k in ("timing", "contention") and v is None)})
    workers = validate_workers(cfg.pop("workers", None))
    mp_context = cfg.pop("mp_context", None)
    if workers > 1:
        from repro.core.desim.parallel import ParallelEngine
        eng = ParallelEngine(machine, workers=workers,
                             mp_context=mp_context, **cfg)
        return eng.restore(trace, ckpt["state"])
    ex = TraceExecutor(machine, **cfg)
    return ex.restore(trace, ckpt["state"])
