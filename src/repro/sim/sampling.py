"""SimPoint/SMARTS-style sampled simulation (gem5 §1.3, §2.7 workflow).

gem5's answer to "a detailed simulation of one minute of wall clock
takes days" is to not simulate most of it in detail: fast-forward with
a cheap functional model, run only sampled windows through the detailed
timing model (SimPoint picks representative windows; SMARTS samples
periodically).  For a steady-state training run the same trick is
almost free: every step executes the same compiled program, so a few
detailed windows pin down contention effects and the rest runs atomic.

``SampledSimulation`` reproduces the periodic (SMARTS) scheme **in the
engine**: one resumable run whose timing model is switched at segment
boundaries (the gem5 ``switch_cpus`` move, through the executor's
drain/serialize/restore path — see ``repro.core.desim.timing``):

* a ``warmup`` segment and periodic ``window``-step windows run under
  ``DetailedTiming`` (full link contention, quantum sync);
* the steps between windows run under ``AtomicTiming`` — real
  in-engine fast-forward: op ticks advance at the contention-free
  analytical rate, **stats keep accumulating** (op counts, busy
  seconds, bytes on wire), and ~zero engine events fire.  There is no
  out-of-engine extrapolation anymore: the final tick *is* the
  simulated time, checkpoints taken mid-fast-forward are real
  checkpoints, and dynamic workloads can fast-forward the same way.

Accuracy/coverage contract (test-enforced in tests/test_sampling.py and
benchmarked in benchmarks/sampled_sim.py): on a >=100-step steady-state
workload the default plan executes <= 20% of ops at detailed fidelity
and lands within 5% of the full-detail total time.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, Iterator, List, Optional, Tuple

from repro.core.desim.simnodes import TICKS_PER_S
from repro.core.desim.trace import HloTrace
from repro.sim.boards import Board
from repro.sim.simulator import ExitEvent, ExitEventType, repeat_trace


@dataclass
class SamplePlan:
    """Periodic sampling schedule over ``num_steps`` training steps.

    ``warmup``   : leading steps always run detailed (cold caches /
                   cold link-occupancy analogue).
    ``interval`` : period length; each period starts with ``window``
                   detailed steps, the rest is fast-forwarded.
    """

    warmup: int = 2
    interval: int = 12
    window: int = 2

    def __post_init__(self):
        if self.window < 1 or self.interval < self.window:
            raise ValueError("need 1 <= window <= interval")

    def segments(self, num_steps: int) -> List[Tuple[str, int]]:
        """Ordered ("detailed"|"ff", n_steps) segments covering the run."""
        segs: List[Tuple[str, int]] = []
        pos = 0
        if self.warmup and num_steps > 0:
            w = min(self.warmup, num_steps)
            segs.append(("detailed", w))
            pos = w
        while pos < num_steps:
            w = min(self.window, num_steps - pos)
            segs.append(("detailed", w))
            pos += w
            ff = min(self.interval - self.window, num_steps - pos)
            if ff > 0:
                segs.append(("ff", ff))
                pos += ff
        return segs

    def detailed_fraction(self, num_steps: int) -> float:
        det = sum(n for kind, n in self.segments(num_steps)
                  if kind == "detailed")
        return det / max(num_steps, 1)


@dataclass
class SimPointPlan:
    """SimPoint sampling schedule: detailed windows picked by phase
    clustering, not a fixed stride (gem5 §1.3; built automatically by
    :func:`repro.sim.fingerprint.simpoint_plan`).

    ``window``          : steps per window (the fingerprint interval).
    ``representatives`` : sorted window indices to run detailed — one
                          per cluster.
    ``weights``         : aligned with ``representatives``; each is the
                          cluster's share of all windows (sums to 1).
    ``labels``          : optional per-window cluster ids (provenance,
                          not used by the schedule).

    ``segments()`` has the same contract as :class:`SamplePlan`, with
    one extra guarantee: detailed segments are never merged, so the
    i-th detailed window of a sampled run is ``representatives[i]`` and
    its measured step time pairs with ``weights[i]`` for the weighted
    reconstruction ``total ≈ num_steps * Σ w_i * step_time_i``.
    """

    window: int = 1
    representatives: List[int] = field(default_factory=list)
    weights: List[float] = field(default_factory=list)
    labels: Optional[List[int]] = None

    def __post_init__(self):
        if self.window < 1:
            raise ValueError("need window >= 1")
        if len(self.weights) != len(self.representatives):
            raise ValueError("weights must align with representatives")
        if list(self.representatives) != sorted(set(self.representatives)):
            raise ValueError("representatives must be sorted and unique")
        if self.weights and abs(sum(self.weights) - 1.0) > 1e-9:
            raise ValueError(
                f"weights must sum to 1 (got {sum(self.weights)})")

    def segments(self, num_steps: int) -> List[Tuple[str, int]]:
        """One ("detailed"|"ff", n_steps) segment per window."""
        reps = set(self.representatives)
        segs: List[Tuple[str, int]] = []
        pos = widx = 0
        while pos < num_steps:
            n = min(self.window, num_steps - pos)
            segs.append(("detailed" if widx in reps else "ff", n))
            pos += n
            widx += 1
        return segs

    def detailed_fraction(self, num_steps: int) -> float:
        det = sum(n for kind, n in self.segments(num_steps)
                  if kind == "detailed")
        return det / max(num_steps, 1)

    def weighted_total_s(self, num_steps: int,
                         window_step_s: List[float]) -> float:
        """SimPoint reconstruction from measured per-step window times
        (aligned with ``representatives``)."""
        if len(window_step_s) != len(self.representatives):
            raise ValueError(
                f"{len(window_step_s)} window times for "
                f"{len(self.representatives)} representatives")
        return num_steps * sum(w * s for w, s
                               in zip(self.weights, window_step_s))


@dataclass
class SampledResult:
    num_steps: int
    detailed_steps: int
    predicted_total_s: float           # in-engine final tick (real time)
    detailed_op_fraction: float        # ops run at detailed fidelity
    window_step_s: List[float]         # per-step time of each window
    atomic_step_s: float               # contention-free roofline estimate
    events: int                        # engine events actually fired
    segments: List[Tuple[str, int]] = field(default_factory=list)
    stats: Optional[Dict[str, Any]] = None   # full-run gem5 stats tree
    # SimPoint reconstruction num_steps * Σ w_i * step_time_i — only
    # set when the plan carries weights (a SimPointPlan).  Unlike
    # predicted_total_s (the in-engine final tick, which times
    # non-representative regions at atomic fidelity), this estimates
    # what a FULL-DETAIL run would cost.
    weighted_total_s: Optional[float] = None

    @property
    def mean_step_s(self) -> float:
        return self.predicted_total_s / max(self.num_steps, 1)


def atomic_step_time_s(board: Board, step: HloTrace) -> float:
    """Closed-form per-step estimate at atomic fidelity: serialize every
    op at its contention-free cost (roofline compute, algorithm-model
    collectives; ``overlap`` collectives hide behind compute)."""
    board.instantiate()
    m = board.machine
    from repro.core.desim.collectives import get_algorithm
    alg = get_algorithm(board.algorithm)
    total = 0.0
    for op in step.ops:
        if op.kind == "compute":
            total += m.pod.chip.compute_time_s(op.flops, op.bytes)
        elif not op.overlap:
            total += alg.time_s(op.kind, op.coll_bytes,
                                op.participants or m.pod.num_chips, m)
    return total


class SampledSimulation:
    """Drive a steady-state workload through a :class:`SamplePlan` as
    ONE in-engine run with mid-run timing-model switches.

    Generator-style like ``Simulator``: ``run()`` yields a
    ``SAMPLE_BEGIN`` exit event before each detailed window and ``DONE``
    at the end; ``result()`` returns the :class:`SampledResult` —
    including the full stats tree, which now covers the fast-forwarded
    regions too (they execute for real at atomic fidelity).

    Window boundaries follow the *pod-0* completion frontier.  On
    multipod boards lagging pods can still be mid-window at a switch:
    their in-flight ops complete under the old model (gem5 drain), but
    their deferred remainder re-times under the new one, and under
    QuantumSync the switch lands on a quantum boundary — so detailed
    windows are step-exact on single-pod boards and quantum/straggler-
    granular on multipod ones (the usual SMARTS sampling-noise caveat,
    not a correctness issue: the run's final tick is still the real
    in-engine time).
    """

    def __init__(self, board: Board, step: HloTrace, num_steps: int,
                 plan: Optional[Any] = None,
                 ff_mode: str = "atomic"):
        if ff_mode != "atomic":
            raise ValueError(
                f"ff_mode {ff_mode!r}: only 'atomic' is supported — "
                "fast-forward now runs in-engine under AtomicTiming "
                "(the analytical 'extrapolate' mode was removed; see "
                "docs/sampling.md)")
        self.board = board.instantiate()
        self.step = step
        self.num_steps = int(num_steps)
        self.plan = plan or SamplePlan()
        self.ff_mode = ff_mode
        # A pre-chained multi-step trace (repeat_trace / chain_steps
        # stamp meta["steps"]) is used as-is: non-steady-state
        # workloads have per-step differences a repeated single step
        # cannot express.  Anything else is a one-step trace repeated.
        self._full_trace = (
            self.num_steps > 1
            and int(step.meta.get("steps", 0)) == self.num_steps)
        if self._full_trace and len(step.ops) % self.num_steps:
            raise ValueError(
                f"chained trace has {len(step.ops)} ops, not divisible "
                f"into {self.num_steps} uniform steps")
        self._result: Optional[SampledResult] = None

    # ------------------------------------------------------------------
    def _switch(self, ex, timing: str):
        """gem5 switch_cpus through the drain/snapshot/restore path.

        Uses the in-memory snapshot directly (not the JSON checkpoint
        file format): a sampled run switches models dozens of times and
        the trace re-serialization would dominate the wall time the
        fast-forward saves.  Semantically identical — the file path is
        covered by ``Simulator.switch_timing`` and the cross-model
        checkpoint tests."""
        ex.drain()
        state = ex.snapshot()
        fresh = self.board.executor(record_stats=True, timing=timing,
                                    straggler_slowdowns=list(ex.slow))
        return fresh.restore(ex._trace, state)

    def run(self) -> Iterator[ExitEvent]:
        segs = self.plan.segments(self.num_steps)
        if self._full_trace:
            trace = self.step
            n_ops = len(trace.ops) // self.num_steps
            atomic = (atomic_step_time_s(self.board, trace)
                      / self.num_steps)
        else:
            n_ops = len(self.step.ops)
            trace = repeat_trace(self.step, self.num_steps)
            atomic = atomic_step_time_s(self.board, self.step)

        progress = {"ops": 0, "detailed_ops": 0, "last_end": 0,
                    "model": "detailed" if segs and segs[0][0] == "detailed"
                             else "atomic"}

        def hook(op, idx, start, end):
            progress["ops"] += 1
            if progress["model"] == "detailed":
                progress["detailed_ops"] += 1
            if end > progress["last_end"]:
                progress["last_end"] = end

        ex = self.board.executor(record_stats=True,
                                 timing=progress["model"])
        ex.op_hook = hook
        ex.begin(trace)

        window_step_s: List[float] = []
        detailed = 0
        pos = 0
        for kind, n in segs:
            want = "detailed" if kind == "detailed" else "atomic"
            # span starts BEFORE any switch: the drain completes the
            # boundary ops already in flight (the next step's compute,
            # issued the moment the previous sinks landed) under the
            # old model — compute costs are model-identical, and those
            # ops belong to THIS segment, so charging them here keeps
            # window_step_s honest (the SimPoint reconstruction
            # multiplies these by cluster weights)
            seg_start = progress["last_end"]
            if want != progress["model"]:
                ex = self._switch(ex, want)
                progress["model"] = want
                ex.op_hook = hook
            if kind == "detailed":
                yield ExitEvent(
                    ExitEventType.SAMPLE_BEGIN,
                    tick=progress["last_end"],
                    cause=f"window @ step {pos} ({n} steps)",
                    payload={"step": pos, "steps": n})
            target = (pos + n) * n_ops
            ex.advance(stop_check=lambda: progress["ops"] >= target)
            if kind == "detailed":
                window_step_s.append(
                    (progress["last_end"] - seg_start) / TICKS_PER_S / n)
                detailed += n
            pos += n
        ex.advance()                 # lagging pods finish the last step
        res = ex.result()

        weighted = None
        if getattr(self.plan, "weights", None):
            weighted = self.plan.weighted_total_s(self.num_steps,
                                                  window_step_s)
        self._result = SampledResult(
            num_steps=self.num_steps,
            detailed_steps=detailed,
            predicted_total_s=res.makespan_s,
            detailed_op_fraction=progress["detailed_ops"] /
            max(self.num_steps * n_ops, 1),
            window_step_s=window_step_s,
            atomic_step_s=atomic,
            events=res.events,
            segments=segs,
            stats=res.stats,
            weighted_total_s=weighted)
        yield ExitEvent(ExitEventType.DONE,
                        tick=res.final_tick,
                        cause=f"sampled {detailed}/{self.num_steps} steps")

    def result(self) -> SampledResult:
        if self._result is None:
            raise RuntimeError("iterate run() to completion first")
        return self._result


def sampled_run(board: Board, step: HloTrace, num_steps: int,
                plan: Optional[Any] = None,
                ff_mode: str = "atomic") -> SampledResult:
    """One-shot sampled simulation (drains the exit-event stream).
    ``plan``: a :class:`SamplePlan` (fixed stride) or
    :class:`SimPointPlan` (phase-clustered; adds ``weighted_total_s``
    to the result)."""
    sim = SampledSimulation(board, step, num_steps, plan, ff_mode)
    for _ in sim.run():
        pass
    return sim.result()
