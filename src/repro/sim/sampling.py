"""SimPoint/SMARTS-style sampled simulation (gem5 §1.3, §2.7 workflow).

gem5's answer to "a detailed simulation of one minute of wall clock
takes days" is to not simulate most of it in detail: fast-forward to
the region of interest with a cheap functional model, run only sampled
windows through the detailed timing model, and extrapolate (SimPoint
picks representative windows; SMARTS samples periodically).  For a
steady-state training run the same trick is almost free: every step
executes the same compiled program, so a few detailed windows pin down
the per-step time and the rest is fast-forwarded.

``SampledSimulation`` reproduces the periodic (SMARTS) scheme:

* a ``warmup`` segment and periodic ``window``-step windows run through
  the full contention-aware desim (``TraceExecutor``);
* the steps between windows are **fast-forwarded**: their ticks advance
  at the estimated per-step rate without any events firing.  Two
  estimators: ``"extrapolate"`` (mean of detailed windows so far — the
  SMARTS extrapolation, default) and ``"atomic"`` (closed-form
  contention-free roofline sum — gem5's atomic fidelity, available
  before any window has run and reported alongside for comparison).

Accuracy/coverage contract (test-enforced in tests/test_sampling.py and
benchmarked in benchmarks/sampled_sim.py): on a >=100-step steady-state
workload the default plan executes <= 20% of ops at detailed fidelity
and predicts the full-detail total time within 5%.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Iterator, List, Optional, Tuple

from repro.core.desim.simnodes import TICKS_PER_S
from repro.core.desim.trace import HloTrace
from repro.sim.boards import Board
from repro.sim.simulator import ExitEvent, ExitEventType, repeat_trace


@dataclass
class SamplePlan:
    """Periodic sampling schedule over ``num_steps`` training steps.

    ``warmup``   : leading steps always run detailed (cold caches /
                   cold link-occupancy analogue).
    ``interval`` : period length; each period starts with ``window``
                   detailed steps, the rest is fast-forwarded.
    """

    warmup: int = 2
    interval: int = 12
    window: int = 2

    def __post_init__(self):
        if self.window < 1 or self.interval < self.window:
            raise ValueError("need 1 <= window <= interval")

    def segments(self, num_steps: int) -> List[Tuple[str, int]]:
        """Ordered ("detailed"|"ff", n_steps) segments covering the run."""
        segs: List[Tuple[str, int]] = []
        pos = 0
        if self.warmup:
            w = min(self.warmup, num_steps)
            segs.append(("detailed", w))
            pos = w
        while pos < num_steps:
            w = min(self.window, num_steps - pos)
            segs.append(("detailed", w))
            pos += w
            ff = min(self.interval - self.window, num_steps - pos)
            if ff > 0:
                segs.append(("ff", ff))
                pos += ff
        return segs

    def detailed_fraction(self, num_steps: int) -> float:
        det = sum(n for kind, n in self.segments(num_steps)
                  if kind == "detailed")
        return det / max(num_steps, 1)


@dataclass
class SampledResult:
    num_steps: int
    detailed_steps: int
    predicted_total_s: float
    detailed_op_fraction: float        # ops run through desim / total ops
    window_step_s: List[float]         # per-step time of each window
    atomic_step_s: float               # contention-free roofline estimate
    events: int                        # engine events actually fired
    segments: List[Tuple[str, int]] = field(default_factory=list)

    @property
    def mean_step_s(self) -> float:
        return self.predicted_total_s / max(self.num_steps, 1)


def atomic_step_time_s(board: Board, step: HloTrace) -> float:
    """Closed-form per-step estimate at atomic fidelity: serialize every
    op at its contention-free cost (roofline compute, algorithm-model
    collectives; ``overlap`` collectives hide behind compute)."""
    board.instantiate()
    m = board.machine
    from repro.core.desim.collectives import get_algorithm
    alg = get_algorithm(board.algorithm)
    total = 0.0
    for op in step.ops:
        if op.kind == "compute":
            total += m.pod.chip.compute_time_s(op.flops, op.bytes)
        elif not op.overlap:
            total += alg.time_s(op.kind, op.coll_bytes,
                                op.participants or m.pod.num_chips, m)
    return total


class SampledSimulation:
    """Drive a steady-state workload through a :class:`SamplePlan`.

    Generator-style like ``Simulator``: ``run()`` yields a
    ``SAMPLE_BEGIN`` exit event before each detailed window and ``DONE``
    at the end; ``result()`` returns the :class:`SampledResult`.
    """

    def __init__(self, board: Board, step: HloTrace, num_steps: int,
                 plan: Optional[SamplePlan] = None,
                 ff_mode: str = "extrapolate"):
        if ff_mode not in ("extrapolate", "atomic"):
            raise ValueError(f"ff_mode {ff_mode!r}: "
                             "'extrapolate' or 'atomic'")
        self.board = board.instantiate()
        self.step = step
        self.num_steps = int(num_steps)
        self.plan = plan or SamplePlan()
        self.ff_mode = ff_mode
        self._result: Optional[SampledResult] = None

    def run(self) -> Iterator[ExitEvent]:
        atomic = atomic_step_time_s(self.board, self.step)
        segs = self.plan.segments(self.num_steps)
        window_step_s: List[float] = []
        total_s = 0.0
        detailed = 0
        events = 0
        pos = 0
        for kind, n in segs:
            if kind == "detailed":
                yield ExitEvent(
                    ExitEventType.SAMPLE_BEGIN,
                    tick=int(round(total_s * TICKS_PER_S)),
                    cause=f"window @ step {pos} ({n} steps)",
                    payload={"step": pos, "steps": n})
                ex = self.board.executor()
                res = ex.execute(repeat_trace(self.step, n))
                window_step_s.append(res.makespan_s / n)
                total_s += res.makespan_s
                detailed += n
                events += res.events
            else:
                if self.ff_mode == "extrapolate" and window_step_s:
                    # SMARTS: extrapolate at the measured detailed rate
                    per_step = sum(window_step_s) / len(window_step_s)
                else:
                    per_step = atomic
                total_s += per_step * n
            pos += n
        ops_per_step = len(self.step.ops)
        self._result = SampledResult(
            num_steps=self.num_steps,
            detailed_steps=detailed,
            predicted_total_s=total_s,
            detailed_op_fraction=(detailed * ops_per_step) /
            max(self.num_steps * ops_per_step, 1),
            window_step_s=window_step_s,
            atomic_step_s=atomic,
            events=events,
            segments=segs)
        yield ExitEvent(ExitEventType.DONE,
                        tick=int(round(total_s * TICKS_PER_S)),
                        cause=f"sampled {detailed}/{self.num_steps} steps")

    def result(self) -> SampledResult:
        if self._result is None:
            raise RuntimeError("iterate run() to completion first")
        return self._result


def sampled_run(board: Board, step: HloTrace, num_steps: int,
                plan: Optional[SamplePlan] = None,
                ff_mode: str = "extrapolate") -> SampledResult:
    """One-shot sampled simulation (drains the exit-event stream)."""
    sim = SampledSimulation(board, step, num_steps, plan, ff_mode)
    for _ in sim.run():
        pass
    return sim.result()
