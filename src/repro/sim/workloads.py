"""Dynamic workloads: ops generated in response to events (tentpole).

The gem5 paper's headline capability is running *full applications* —
work is created by the simulated system as it runs, not replayed from a
frozen trace.  This module brings that to g5x: a
:class:`DynamicWorkload` interface whose implementations inject ops
into a live :class:`~repro.core.desim.executor.TraceExecutor` run
(``inject_op``), driven by ``repro.sim.Simulator``'s exit-event loop.

Two flagship implementations:

* :class:`ServeSim` — request-level, vLLM-style continuous-batching
  LLM serving at pod scale (below);
* :class:`TrainSim` — fault-injected large-scale training: roofline-
  costed steps under a seeded failure schedule, with recovery driven
  by the pure ``repro.train.ft_policy.FTPolicy`` the real ``Trainer``
  uses (see the class docstring at the bottom of this module).

* **Arrivals are events** — open-loop (Poisson or a recorded trace of
  arrival times) or closed-loop (a fixed client population, each
  submitting its next request when the previous one finishes plus think
  time).  All randomness comes from one explicit ``seed``.
* **The scheduling policy is the real one** — each pod replica drives a
  :class:`repro.serve.policy.SlotScheduler`, the *identical* pure
  policy object ``repro.serve.server.BatchServer`` uses, so DES and
  real-server scheduling decisions match exactly (test-enforced).
* **Phases are roofline-costed** — an admitted request injects a
  prefill compute op; each engine iteration injects one batched decode
  op whose flops/bytes follow the standard LLM serving roofline
  (weight-read-bound decode, compute-bound prefill) via
  :class:`ServingCost`; execution time then comes from the machine
  model's ``compute_time_s`` like every other op in the DES.
* **KV-cache slots are the contended resource** — ``slots`` x
  ``seq_capacity`` tokens per replica; requests queue when slots are
  full (the queue wait shows up in TTFT).
* **SLOs are exit events** — TTFT/latency targets; violations count in
  stats and (with ``exit_on_slo``) surface as ``SLO_VIOLATION`` exit
  events from ``Simulator.run()``.

Checkpointing: ``state_dict``/``load_state_dict`` capture pending
arrivals, per-replica scheduler state (including the decision log),
in-flight request runtimes, and the percentile-stat accumulators; the
executor side (in-flight/deferred injected ops) rides in the normal
drain-then-serialize snapshot, so a run restored mid-serving finishes
bit-identically (tests/test_sim_checkpoint.py).

Fidelity: both workloads inject only per-pod *compute* ops, so they
are **tick-exact under AtomicTiming** (``timing="atomic"`` — same
makespan, same decision logs, ~zero engine events; test-enforced in
tests/test_timing_models.py).  The big serving/FT sweeps
(``benchmarks/serving_sweep.py``, ``benchmarks/ft_sweep.py``) default
to atomic with a detailed spot-check for exactly this reason; see
``docs/fidelity.md``.
"""

from __future__ import annotations

import hashlib
import heapq
import json
import random
from collections import deque
from dataclasses import dataclass
from typing import Any, Deque, Dict, List, Optional, Sequence, Tuple

from repro.core.desim.simnodes import TICKS_PER_S, to_ticks
from repro.core.desim.trace import TraceOp
from repro.core.simobject import Param, SimObject
from repro.serve.policy import SlotScheduler
from repro.train.ft_policy import (FailureSchedule, FTDecision, FTPolicy,
                                   StepPlan)


# ---------------------------------------------------------------------------
# requests and arrival processes
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ServeRequest:
    """One simulated request.  ``rid`` equals its index in the request
    list (the stable identity used by schedulers and checkpoints)."""

    rid: int
    prompt_len: int
    decode_len: int          # max_new_tokens of the real server
    arrival_tick: int = 0    # open-loop arrival time (ignored closed-loop)


def poisson_requests(num_requests: int, rate_rps: float, *, seed: int,
                     prompt_len: Tuple[int, int] = (64, 512),
                     decode_len: Tuple[int, int] = (16, 128)
                     ) -> List[ServeRequest]:
    """Open-loop Poisson arrival stream with uniform prompt/decode
    lengths, fully determined by ``seed`` (reproducible sweeps)."""
    if rate_rps <= 0:
        raise ValueError("rate_rps must be positive")
    rng = random.Random(seed)
    t = 0.0
    out: List[ServeRequest] = []
    for i in range(num_requests):
        t += rng.expovariate(rate_rps)
        out.append(ServeRequest(
            rid=i,
            prompt_len=rng.randint(*prompt_len),
            decode_len=rng.randint(*decode_len),
            arrival_tick=to_ticks(t)))
    return out


def trace_requests(rows: Sequence[Tuple[float, int, int]]) -> List[ServeRequest]:
    """Trace-driven arrivals from ``(arrival_s, prompt_len, decode_len)``
    rows (e.g. replayed from production logs)."""
    ordered = sorted(rows, key=lambda r: r[0])
    return [ServeRequest(rid=i, prompt_len=int(p), decode_len=int(d),
                         arrival_tick=to_ticks(s))
            for i, (s, p, d) in enumerate(ordered)]


def uniform_requests(num_requests: int, *, seed: int,
                     prompt_len: Tuple[int, int] = (64, 512),
                     decode_len: Tuple[int, int] = (16, 128)
                     ) -> List[ServeRequest]:
    """Request dimensions without arrival times — the closed-loop pool
    (clients set the timing) or an all-at-tick-0 batch."""
    rng = random.Random(seed)
    return [ServeRequest(rid=i, prompt_len=rng.randint(*prompt_len),
                         decode_len=rng.randint(*decode_len))
            for i in range(num_requests)]


# ---------------------------------------------------------------------------
# serving roofline cost model
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ServingCost:
    """Linear roofline cost model of one serving replica.

    The standard LLM inference model: a forward pass moves every
    resident weight byte once and touches each request's KV cache;
    flops scale with tokens processed.  Per-op times then come from
    ``ChipModel.compute_time_s`` (max of compute and HBM terms) — the
    same roofline machinery every compute op in the DES uses.

    All quantities are whole-model; ``chips`` shards them over the
    replica's chips (per-chip values are what ``TraceOp`` carries).
    """

    flops_per_token: float    # forward FLOPs per processed token (~2*params)
    weight_bytes: float       # resident weight bytes read per pass
    kv_bytes_per_token: float  # KV bytes appended/read per context token
    chips: int = 1

    def prefill_cost(self, prompt_len: int) -> Tuple[float, float]:
        """(flops, bytes) per chip to prefill ``prompt_len`` tokens."""
        flops = self.flops_per_token * prompt_len
        nbytes = self.weight_bytes + self.kv_bytes_per_token * prompt_len
        return flops / self.chips, nbytes / self.chips

    def decode_cost(self, batch: int, context_tokens: int
                    ) -> Tuple[float, float]:
        """(flops, bytes) per chip for one batched decode step over
        ``batch`` active slots with ``context_tokens`` total context."""
        flops = self.flops_per_token * batch
        nbytes = (self.weight_bytes
                  + self.kv_bytes_per_token * (context_tokens + batch))
        return flops / self.chips, nbytes / self.chips

    def kv_slot_bytes(self, seq_capacity: int) -> float:
        """HBM footprint of one full KV slot (capacity planning)."""
        return self.kv_bytes_per_token * seq_capacity

    # -- constructors ----------------------------------------------------
    @classmethod
    def from_params(cls, num_params: float, *, layers: int, d_model: int,
                    dtype_bytes: float = 2.0, chips: int = 1
                    ) -> "ServingCost":
        """Analytic model from architecture shape: 2 flops per param per
        token, K+V rows of ``d_model`` per layer per token."""
        return cls(flops_per_token=2.0 * num_params,
                   weight_bytes=num_params * dtype_bytes,
                   kv_bytes_per_token=2.0 * layers * d_model * dtype_bytes,
                   chips=chips)

    @classmethod
    def from_hlo_cost(cls, decode_cost, *, batch: int, context_tokens: int,
                      weight_bytes: float, chips: int = 1) -> "ServingCost":
        """Fit the model from an analyzed decode step (a
        ``repro.core.desim.hlo_cost.Cost`` of one compiled batched
        decode): flops are per batch element; bytes beyond the known
        resident weights are attributed to KV traffic."""
        kv = max(0.0, decode_cost.bytes - weight_bytes) \
            / max(context_tokens + batch, 1)
        return cls(flops_per_token=decode_cost.flops / max(batch, 1),
                   weight_bytes=weight_bytes, kv_bytes_per_token=kv,
                   chips=chips)


# ---------------------------------------------------------------------------
# the dynamic-workload interface
# ---------------------------------------------------------------------------

class DynamicWorkload:
    """A workload that generates ops while the simulation runs.

    ``Simulator`` drives it as a co-simulation: the executor advances to
    the workload's next event tick, then ``poll(tick)`` lets the
    workload react (inject ops, submit requests).  Op completions reach
    the workload synchronously through the executor's
    ``injection_hook``, so the engine's internal feedback loops (e.g. a
    decode step triggering the next) never leave the event engine.
    """

    #: exit events for ``Simulator.run`` (dicts: tick/cause/payload)
    pending_exits: Deque[Dict[str, Any]]

    def bind(self, executor) -> None:
        raise NotImplementedError

    def start(self) -> None:
        raise NotImplementedError

    def next_event_tick(self) -> Optional[int]:
        raise NotImplementedError

    def poll(self, tick: int) -> None:
        raise NotImplementedError

    def done(self) -> bool:
        raise NotImplementedError

    def state_dict(self) -> Dict[str, Any]:
        raise NotImplementedError

    def load_state_dict(self, d: Dict[str, Any]) -> None:
        raise NotImplementedError


class _Replica:
    """One serving replica (one pod): a slot scheduler plus in-flight
    tracking.  ``busy`` is True while a decode chain is in the engine;
    an idle replica is woken by the next arrival."""

    def __init__(self, pod: int, sched: SlotScheduler):
        self.pod = pod
        self.sched = sched
        self.busy = False


# ---------------------------------------------------------------------------
# ServeSim
# ---------------------------------------------------------------------------

class ServeSim(SimObject, DynamicWorkload):
    """Request-level continuous-batching serving on the event engine.

    One replica per pod of the board's machine; requests are dispatched
    round-robin by rid (a deterministic load balancer).  See the module
    docstring for the model; see ``docs/serving.md`` for the
    correspondence to ``repro.serve.server.BatchServer``.
    """

    slots = Param(int, 8, "KV-cache slots (decode batch) per replica",
                  check=lambda v: v >= 1)
    seq_capacity = Param(int, 2048, "KV capacity (tokens) per slot",
                         check=lambda v: v >= 2)
    slo_ttft_s = Param(float, 0.0, "TTFT SLO in seconds (0 = none)")
    slo_latency_s = Param(float, 0.0, "request-latency SLO (0 = none)")
    exit_on_slo = Param(bool, False,
                        "surface each SLO violation as an exit event")
    closed_loop_clients = Param(int, 0,
                                "closed-loop client population (0 = open loop)")
    think_time_s = Param(float, 0.0, "closed-loop think time per client")

    def __init__(self, name: str = "serve", *, cost: ServingCost,
                 requests: List[ServeRequest], **params):
        super().__init__(name, **params)
        if not requests:
            raise ValueError("ServeSim needs at least one request")
        for i, r in enumerate(requests):
            if r.rid != i:
                raise ValueError(f"request {i} has rid {r.rid}; rids must "
                                 "equal list indices")
            # fail at construction, not at the request's arrival tick
            # deep inside a long simulation
            if r.prompt_len >= self.seq_capacity:
                raise ValueError(
                    f"request {i}: prompt_len {r.prompt_len} does not fit "
                    f"seq_capacity {self.seq_capacity}")
            if r.decode_len < 1 or r.prompt_len < 1:
                raise ValueError(
                    f"request {i}: prompt_len/decode_len must be >= 1")
        self.cost = cost
        self._requests = list(requests)
        self._ex = None
        self._reps: Optional[List[_Replica]] = None
        self._heap: List[Tuple[int, int]] = []      # (arrival_tick, rid)
        self._cursor = 0           # next rid a closed-loop client takes
        self._done_count = 0
        self._started = False
        self.pending_exits: Deque[Dict[str, Any]] = deque()
        # rid -> runtime ticks (submit/first token/finish) + SLO verdict
        self._rt: Dict[int, Dict[str, Any]] = {}
        s = self.stats
        self.s_admitted = s.scalar("admitted", "requests admitted to slots")
        self.s_requests = s.scalar("requests_done", "requests completed")
        self.s_tokens = s.scalar("tokens_out", "decode tokens generated")
        self.s_decode_steps = s.scalar("decode_steps", "batched decode steps")
        self.s_prefills = s.scalar("prefills", "prefill ops run")
        self.s_slo_viol = s.scalar("slo_violations", "requests over SLO")
        self.p_ttft = s.percentiles("ttft", "time to first token", "s")
        self.p_tpot = s.percentiles("tpot", "time per output token", "s")
        self.p_latency = s.percentiles("latency", "request latency", "s")
        self.p_queue_wait = s.percentiles("queue_wait",
                                          "arrival-to-admission wait", "s")
        self.d_batch = s.distribution("decode_batch",
                                      "active slots per decode step")
        s.formula("tokens_per_step",
                  lambda: self.s_tokens.value()
                  / max(self.s_decode_steps.value(), 1.0))

    # -- DynamicWorkload: lifecycle --------------------------------------
    def bind(self, executor) -> None:
        """Attach to a (possibly freshly restored) executor.  Replica
        state is created once; re-binding after a checkpoint restore
        keeps it."""
        self._ex = executor
        executor.injection_hook = self._on_op_done
        if self._reps is None:
            pods = executor.machine.num_pods
            self._reps = [_Replica(p, SlotScheduler(self.slots,
                                                    self.seq_capacity))
                          for p in range(pods)]

    def start(self) -> None:
        if self._started:
            return
        self._started = True
        if self.closed_loop_clients > 0:
            # each client submits its first request at tick 0
            first = min(self.closed_loop_clients, len(self._requests))
            self._heap = [(0, i) for i in range(first)]
            self._cursor = first
        else:
            self._heap = [(r.arrival_tick, r.rid) for r in self._requests]
            self._cursor = len(self._requests)
        heapq.heapify(self._heap)

    def next_event_tick(self) -> Optional[int]:
        return self._heap[0][0] if self._heap else None

    def poll(self, tick: int) -> None:
        self._catch_up(int(tick))

    def done(self) -> bool:
        return self._done_count == len(self._requests)

    # -- the serving engine ----------------------------------------------
    def _catch_up(self, t: int) -> None:
        """Submit every arrival with tick <= ``t``, in tick order,
        waking idle replicas at the exact arrival tick.  All arrivals
        sharing one tick are submitted *before* any replica wakes (the
        server submits its whole batch before the first fill).  Called
        from ``poll`` and from decode completions, so arrival
        interleaving is identical whether the run pauses/drains or not.
        """
        while self._heap and self._heap[0][0] <= t:
            tick = self._heap[0][0]
            touched: List[_Replica] = []
            while self._heap and self._heap[0][0] == tick:
                _, rid = heapq.heappop(self._heap)
                req = self._requests[rid]
                rep = self._reps[rid % len(self._reps)]
                rep.sched.submit(rid, req.prompt_len, req.decode_len)
                self._rt[rid] = {"submit": tick, "first": -1, "finish": -1,
                                 "ok": True}
                if rep not in touched:
                    touched.append(rep)
            for rep in touched:
                if not rep.busy:
                    self._iteration(rep, tick)

    def _iteration(self, rep: _Replica, now: int) -> None:
        """One continuous-batching iteration: admit waiting requests
        (injecting their prefills), then inject the batched decode step
        over all active slots.  Mirrors the BatchServer loop body."""
        sched = rep.sched
        prefill_deps = []
        for slot, rid in sched.fill():
            req = self._requests[rid]
            self.s_admitted.inc()
            self.s_prefills.inc()
            self.p_queue_wait.sample(
                (now - self._rt[rid]["submit"]) / TICKS_PER_S)
            fl, by = self.cost.prefill_cost(req.prompt_len)
            prefill_deps.append(self._ex.inject_op(
                TraceOp("compute", flops=fl, bytes=by,
                        name=f"serve/p{rep.pod}/prefill/r{rid}"),
                ready=now, pod=rep.pod))
        active = sched.active_slots()
        if not active:
            rep.busy = False
            return
        ctx = sum(sched.context_len(s) for s in active)
        fl, by = self.cost.decode_cost(len(active), ctx)
        self.d_batch.sample(len(active))
        self._ex.inject_op(
            TraceOp("compute", flops=fl, bytes=by, deps=tuple(prefill_deps),
                    name=f"serve/p{rep.pod}/decode/s{sched.steps}"),
            ready=now, pod=rep.pod)
        rep.busy = True

    def _on_op_done(self, op: TraceOp, idx: int, pod: int, start: int,
                    end: int) -> None:
        parts = (op.name or "").split("/")
        if len(parts) < 3 or parts[0] != "serve":
            return
        rep = self._reps[pod]
        if parts[2] == "prefill":
            rid = int(parts[3][1:])
            rt = self._rt[rid]
            rt["first"] = end
            self.p_ttft.sample((end - rt["submit"]) / TICKS_PER_S)
            return
        # one batched decode step completed: advance every active slot
        sched = rep.sched
        sched.note_step()
        self.s_decode_steps.inc()
        for slot in sched.active_slots():
            rid = sched.active[slot]
            self.s_tokens.inc()
            fin = sched.complete_token(slot)
            if fin is not None:
                self._finish(rid, end, sched)
        # arrivals up to this tick join the queue before the next fill
        self._catch_up(end)
        self._iteration(rep, end)

    def _finish(self, rid: int, end: int, sched: SlotScheduler) -> None:
        rt = self._rt[rid]
        rt["finish"] = end
        latency = (end - rt["submit"]) / TICKS_PER_S
        tokens = sched.requests[rid].tokens_out
        ttft = (rt["first"] - rt["submit"]) / TICKS_PER_S
        tpot = ((end - rt["first"]) / TICKS_PER_S) / max(tokens - 1, 1)
        self.p_latency.sample(latency)
        self.p_tpot.sample(tpot)
        self.s_requests.inc()
        self._done_count += 1
        violated = ((self.slo_ttft_s > 0 and ttft > self.slo_ttft_s)
                    or (self.slo_latency_s > 0
                        and latency > self.slo_latency_s))
        if violated:
            rt["ok"] = False
            self.s_slo_viol.inc()
            if self.exit_on_slo:
                self.pending_exits.append({
                    "tick": end, "cause": f"slo violation: request {rid}",
                    "payload": {"rid": rid, "ttft_s": ttft,
                                "latency_s": latency}})
        if self.closed_loop_clients > 0 and self._cursor < len(self._requests):
            nxt = self._cursor
            self._cursor += 1
            heapq.heappush(self._heap,
                           (end + to_ticks(self.think_time_s), nxt))

    # -- results -----------------------------------------------------------
    @property
    def schedulers(self) -> List[SlotScheduler]:
        """Per-replica schedulers (decision logs live here)."""
        if self._reps is None:
            raise RuntimeError("ServeSim not bound to an executor yet")
        return [rep.sched for rep in self._reps]

    def summary(self) -> Dict[str, float]:
        """Serving-level result row (the goodput/SLO frontier point).

        ``span_s`` is the active window — first *submitted* request to
        last finish — not tick 0 to last finish: a trace replayed with
        an arrival offset (say production logs starting at t=1000 s)
        must report its real throughput, not one diluted by the idle
        lead-in.  Percentile keys are NaN when no sample landed, so a
        zero-finish run can never masquerade as a perfect one.
        """
        finished = [rt for rt in self._rt.values() if rt["finish"] >= 0]
        if finished:
            first = min(rt["submit"] for rt in finished)
            span_s = (max(rt["finish"] for rt in finished)
                      - first) / TICKS_PER_S
        else:
            span_s = 0.0
        ok = sum(1 for rt in finished if rt["ok"])

        def nan_if_empty(stat, value: float) -> float:
            return value if stat.count else float("nan")

        return {
            "requests": float(len(finished)),
            "span_s": span_s,
            "throughput_rps": len(finished) / span_s if span_s else 0.0,
            "goodput_rps": ok / span_s if span_s else 0.0,
            "slo_violations": self.s_slo_viol.value(),
            "tokens_out": self.s_tokens.value(),
            "p50_ttft_s": nan_if_empty(self.p_ttft,
                                       self.p_ttft.quantile(0.50)),
            "p99_ttft_s": nan_if_empty(self.p_ttft,
                                       self.p_ttft.quantile(0.99)),
            "p50_latency_s": nan_if_empty(self.p_latency,
                                          self.p_latency.quantile(0.50)),
            "p99_latency_s": nan_if_empty(self.p_latency,
                                          self.p_latency.quantile(0.99)),
            "mean_tpot_s": nan_if_empty(self.p_tpot, self.p_tpot.mean),
            "mean_batch": nan_if_empty(self.d_batch, self.d_batch.mean),
        }

    # -- checkpointing -----------------------------------------------------
    def state_dict(self) -> Dict[str, Any]:
        return {
            "num_requests": len(self._requests),
            "started": self._started,
            "cursor": self._cursor,
            "done_count": self._done_count,
            "heap": sorted([t, r] for t, r in self._heap),
            "runtime": {str(rid): dict(rt) for rid, rt in self._rt.items()},
            "reps": [{"pod": rep.pod, "busy": rep.busy,
                      "sched": rep.sched.state_dict()}
                     for rep in (self._reps or [])],
            "pending_exits": [dict(e) for e in self.pending_exits],
            "stats": self.stats.state_dict(),
        }

    def load_state_dict(self, d: Dict[str, Any]) -> None:
        if int(d["num_requests"]) != len(self._requests):
            raise ValueError(
                f"checkpoint has {d['num_requests']} requests, this "
                f"ServeSim {len(self._requests)} — rebuild the workload "
                "with the same request stream (same seed/params)")
        if self._reps is None:
            raise RuntimeError("bind() the ServeSim before loading state")
        if len(d["reps"]) != len(self._reps):
            raise ValueError(
                f"checkpoint has {len(d['reps'])} replicas, machine has "
                f"{len(self._reps)} pods")
        self._started = bool(d["started"])
        self._cursor = int(d["cursor"])
        self._done_count = int(d["done_count"])
        self._heap = [(int(t), int(r)) for t, r in d["heap"]]
        heapq.heapify(self._heap)
        self._rt = {int(rid): dict(rt) for rid, rt in d["runtime"].items()}
        for rep, rd in zip(self._reps, d["reps"]):
            rep.busy = bool(rd["busy"])
            rep.sched = SlotScheduler(self.slots, self.seq_capacity)
            rep.sched.load_state_dict(rd["sched"])
        self.pending_exits = deque(dict(e) for e in d["pending_exits"])
        self.stats.load_state_dict(d["stats"])


# ---------------------------------------------------------------------------
# training roofline cost model
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class TrainStepCost:
    """Roofline cost of one training step (and its FT overheads).

    All quantities are PER CHIP at full-fleet capacity; when the
    elastic mesh shrinks to a fraction ``capacity`` of the chips, the
    surviving chips each carry ``1/capacity`` of these (the sharded
    work redistributes).  Per-op times come from the machine model's
    ``compute_time_s`` roofline like every other op in the DES.
    """

    step_flops: float        # training-step FLOPs per chip (fwd+bwd)
    step_bytes: float        # HBM bytes per chip per step
    ckpt_bytes: float        # checkpoint write bytes per chip
    restore_bytes: float = 0.0   # restore read + restart bytes per chip

    # -- constructors ----------------------------------------------------
    @classmethod
    def from_hlo_cost(cls, step_cost, *, state_bytes: float,
                      chips: int = 1, restore_factor: float = 1.5
                      ) -> "TrainStepCost":
        """From an analyzed compiled train step (a
        ``repro.core.desim.hlo_cost.Cost``, already per-device) plus the
        whole-model optimizer-state size.  ``restore_factor`` covers
        restore read + re-init being slower than the write."""
        per = state_bytes / max(chips, 1)
        return cls(step_flops=step_cost.flops, step_bytes=step_cost.bytes,
                   ckpt_bytes=per, restore_bytes=per * restore_factor)

    @classmethod
    def from_params(cls, num_params: float, *, tokens_per_batch: int,
                    dtype_bytes: float = 2.0, optim_bytes: float = 12.0,
                    chips: int = 1, restore_factor: float = 1.5
                    ) -> "TrainStepCost":
        """Analytic model: 6 flops per param per token (fwd+bwd),
        ~3 weight passes of HBM traffic per step, and a checkpoint of
        weights + optimizer state (``optim_bytes`` per param: fp32
        master + two Adam moments by default).  ``restore_factor``
        covers restore read + re-init being slower than the write."""
        state = num_params * (dtype_bytes + optim_bytes) / max(chips, 1)
        return cls(
            step_flops=6.0 * num_params * tokens_per_batch / max(chips, 1),
            step_bytes=3.0 * num_params * dtype_bytes / max(chips, 1),
            ckpt_bytes=state, restore_bytes=restore_factor * state)


# ---------------------------------------------------------------------------
# TrainSim
# ---------------------------------------------------------------------------

class TrainSim(SimObject, DynamicWorkload):
    """Fault-injected large-scale training on the event engine.

    The training counterpart of :class:`ServeSim`: steps are injected
    into the live run one at a time (``inject_op``), costed by the
    :class:`TrainStepCost` roofline, and a seeded
    :class:`~repro.train.ft_policy.FailureSchedule` drives
    checkpoint / declare-dead / elastic-reshard decisions through the
    *identical* pure :class:`~repro.train.ft_policy.FTPolicy` the real
    ``Trainer.run_ft`` loop uses — so DES and real-trainer recovery
    decision logs match exactly (tests/test_train_ft_policy.py).

    Timeline model (one op chain on pod 0; the SPMD fleet is folded
    into the per-chip roofline costs, scaled by the elastic mesh's
    ``capacity``):

    * a ``step`` attempt costs ``step_flops/bytes * slowdown /
      capacity`` (stragglers slow the whole SPMD step);
    * a checkpoint (cadence or preemption notice) costs
      ``ckpt_bytes / capacity`` of HBM traffic;
    * a ``stall`` attempt (a silent pod hangs the collective until the
      policy declares it dead) costs one nominal step;
    * a ``recover`` attempt costs ``restore_bytes / capacity``.

    Pod deaths and mesh reshards surface as ``POD_FAILED`` / ``RESHARD``
    exit events from ``Simulator.run()`` (``exit_on_fault``).
    Checkpoint/restore of the *simulation* (``state_dict`` /
    ``load_state_dict`` + the executor snapshot) is bit-identical even
    mid-failure-recovery, like every other workload.
    """

    exit_on_fault = Param(bool, True,
                          "surface pod deaths / reshards as exit events")

    def __init__(self, name: str = "train", *, cost: TrainStepCost,
                 policy: FTPolicy, schedule: FailureSchedule, **params):
        super().__init__(name, **params)
        self.cost = cost
        self.policy = policy
        self.schedule = schedule
        self._ex = None
        self._chip = None
        self._started = False
        self._phases: Deque[List[Any]] = deque()   # [tag, flops, bytes]
        self._seq = 0
        self._last_end = 0
        self._done_steps = 0     # step ops COMPLETED, net of rollbacks
        self.pending_exits: Deque[Dict[str, Any]] = deque()
        s = self.stats
        self.s_attempts = s.scalar("attempts", "step executions attempted")
        self.s_steps = s.scalar("steps_done", "step executions completed")
        self.s_stalls = s.scalar("stalls", "attempts hung on a silent pod")
        self.s_failures = s.scalar("pods_dead", "pods declared dead")
        self.s_preempts = s.scalar("preemptions", "pods preempted")
        self.s_joins = s.scalar("pods_joined", "pods (re)joined")
        self.s_stragglers = s.scalar("stragglers", "straggler episodes")
        self.s_ckpts = s.scalar("checkpoints", "checkpoints written")
        self.s_restores = s.scalar("restores", "checkpoint restores")
        self.s_reshards = s.scalar("reshards", "elastic mesh reshards")
        self.s_lost = s.scalar("lost_steps", "completed steps rolled back")
        self.p_step = s.percentiles("step_time", "per-step sim time", "s")
        s.formula("goodput", lambda: self.goodput())

    # -- DynamicWorkload: lifecycle --------------------------------------
    def bind(self, executor) -> None:
        self._ex = executor
        self._chip = executor.machine.pod.chip
        executor.injection_hook = self._on_op_done

    def start(self) -> None:
        if self._started:
            return
        self._started = True
        for d in self.policy.start():
            self._note(d, 0)
        # the policy's initial checkpoint is a real (costed) write
        self._phases.append(["ckpt", 0.0,
                             self.cost.ckpt_bytes
                             / max(self.policy.capacity(), 1e-9)])
        self._advance_chain(0)

    def next_event_tick(self) -> Optional[int]:
        return None          # self-driving: completions trigger injection

    def poll(self, tick: int) -> None:
        pass

    def done(self) -> bool:
        return (self._started and self.policy.done()
                and not self._phases)

    # -- the training engine ---------------------------------------------
    def _advance_chain(self, now: int) -> None:
        while not self._phases and not self.policy.done():
            plan = self.policy.execute_step(
                self.schedule.events_at(self.policy.attempt))
            self._account(plan)
            for d in plan.decisions:
                self._note(d, now)
            self._plan_phases(plan)
        if self._phases:
            tag, fl, by = self._phases.popleft()
            self._seq += 1
            self._ex.inject_op(
                TraceOp("compute", flops=fl, bytes=by,
                        name=f"train/{tag}/{self._seq}"),
                ready=int(now), pod=0)

    def _plan_phases(self, plan: StepPlan) -> None:
        cap = max(plan.capacity, 1e-9)
        c = self.cost
        if plan.pre_save is not None:
            self._phases.append(["ckpt", 0.0, c.ckpt_bytes / cap])
        if plan.kind == "step":
            self._phases.append(["step", c.step_flops * plan.slowdown / cap,
                                 c.step_bytes * plan.slowdown / cap])
            if plan.post_save is not None:
                self._phases.append(["ckpt", 0.0, c.ckpt_bytes / cap])
        elif plan.kind == "stall":
            # the collective hangs for one heartbeat (~one step time)
            self._phases.append(["stall", c.step_flops / cap,
                                 c.step_bytes / cap])
        else:                                    # "recover"
            self._phases.append(["restore", 0.0, c.restore_bytes / cap])

    def _account(self, plan: StepPlan) -> None:
        # "step" completions count in _on_op_done, so a mid-run stats
        # snapshot never includes the in-flight step
        self.s_attempts.inc()
        if plan.kind == "stall":
            self.s_stalls.inc()
        elif plan.kind == "recover":
            self.s_lost.inc(plan.lost_steps)
            # the rolled-back steps all finished executing (ops run
            # sequentially), so the completed counter rewinds exactly
            self._done_steps -= plan.lost_steps

    def _note(self, d: FTDecision, tick: int) -> None:
        kind_stat = {"checkpoint": self.s_ckpts,
                     "pod_dead": self.s_failures,
                     "pod_joined": self.s_joins,
                     "preempt": self.s_preempts,
                     "straggler": self.s_stragglers,
                     "restore": self.s_restores,
                     "reshard": self.s_reshards}.get(d.kind)
        if kind_stat is not None:
            kind_stat.inc()
        if not self.exit_on_fault:
            return
        if d.kind == "pod_dead":
            self.pending_exits.append({
                "tick": tick, "kind": "pod_failed",
                "cause": f"pod {d.pod} dead at step {d.step} "
                         f"(attempt {d.attempt})",
                "payload": {"pod": d.pod, "step": d.step,
                            "attempt": d.attempt, "note": d.note}})
        elif d.kind == "reshard":
            self.pending_exits.append({
                "tick": tick, "kind": "reshard",
                "cause": f"reshard to {'x'.join(map(str, d.mesh))} "
                         f"({d.chips} chips) at step {d.step}",
                "payload": {"mesh": list(d.mesh), "chips": d.chips,
                            "step": d.step, "attempt": d.attempt}})

    def _on_op_done(self, op: TraceOp, idx: int, pod: int, start: int,
                    end: int) -> None:
        name = op.name or ""
        if not name.startswith("train/"):
            return
        self._last_end = max(self._last_end, end)
        if name.split("/")[1] == "step":
            self._done_steps += 1
            self.s_steps.inc()
            self.p_step.sample((end - start) / TICKS_PER_S)
        self._advance_chain(end)

    # -- results -----------------------------------------------------------
    def ideal_step_s(self) -> float:
        """Full-capacity fault-free step time on the bound machine."""
        if self._chip is None:
            raise RuntimeError("TrainSim not bound to an executor yet")
        return self._chip.compute_time_s(self.cost.step_flops,
                                         self.cost.step_bytes)

    def goodput(self) -> float:
        """Useful work over wall time: ``completed_steps *
        ideal_step_time / makespan`` (1.0 = fault-free, full-capacity
        perfection).  Counts *net* completed steps (rollbacks
        subtract), so a mid-run read — a stats dump at a pause, a
        checkpoint — is honest, not scaled to the full plan."""
        if self._chip is None or self._last_end <= 0:
            return 0.0
        ideal = self._done_steps * self.ideal_step_s()
        return ideal / (self._last_end / TICKS_PER_S)

    def summary(self) -> Dict[str, float]:
        """Training-run result row (the goodput frontier point)."""
        return {
            "steps": float(self.policy.num_steps),
            "attempts": self.s_attempts.value(),
            "makespan_s": self._last_end / TICKS_PER_S,
            "ideal_step_s": self.ideal_step_s(),
            "goodput": self.goodput(),
            "pods_dead": self.s_failures.value(),
            "stalls": self.s_stalls.value(),
            "checkpoints": self.s_ckpts.value(),
            "restores": self.s_restores.value(),
            "reshards": self.s_reshards.value(),
            "lost_steps": self.s_lost.value(),
        }

    # -- checkpointing -----------------------------------------------------
    def _schedule_digest(self) -> str:
        rows = [[e.attempt, e.kind, e.pod, e.slowdown, e.duration,
                 e.repair] for e in self.schedule.events]
        return hashlib.sha1(json.dumps(rows).encode()).hexdigest()[:16]

    def state_dict(self) -> Dict[str, Any]:
        return {
            "num_events": len(self.schedule.events),
            "schedule_digest": self._schedule_digest(),
            "started": self._started,
            "seq": self._seq,
            "last_end": self._last_end,
            "done_steps": self._done_steps,
            "phases": [list(p) for p in self._phases],
            "policy": self.policy.state_dict(),
            "pending_exits": [dict(e) for e in self.pending_exits],
            "stats": self.stats.state_dict(),
        }

    def load_state_dict(self, d: Dict[str, Any]) -> None:
        mine = self._schedule_digest()
        if int(d["num_events"]) != len(self.schedule.events) \
                or d.get("schedule_digest", mine) != mine:
            raise ValueError(
                "checkpoint was taken under a different failure "
                f"schedule ({d['num_events']} events, digest "
                f"{d.get('schedule_digest')}) than this TrainSim's "
                f"({len(self.schedule.events)} events, digest {mine}) "
                "— rebuild with the same seed/params")
        self._started = bool(d["started"])
        self._seq = int(d["seq"])
        self._last_end = int(d["last_end"])
        self._done_steps = int(d.get("done_steps", 0))
        self._phases = deque([p[0], float(p[1]), float(p[2])]
                             for p in d["phases"])
        self.policy.load_state_dict(d["policy"])
        self.pending_exits = deque(dict(e) for e in d["pending_exits"])
        self.stats.load_state_dict(d["stats"])
