"""Training launcher: ``python -m repro.launch.train --arch <id> ...``.

On this CPU container it trains REDUCED (smoke) configs for real; on a
TPU pod the same driver takes ``--full`` and the production mesh.  The
trainer is the SimObject loop from ``repro.train.trainer`` with
checkpointing, heartbeat, straggler watchdog, and deterministic data.
"""

from __future__ import annotations

import argparse
import json

import jax

from repro.configs import REGISTRY, get_config, smoke
from repro.configs.base import ShapeConfig
from repro.data import SyntheticPipeline
from repro.models import build_model
from repro.train import TrainOptions, build_train_step, init_train_state
from repro.train.step import default_options_for
from repro.train.trainer import Trainer


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-1.6b",
                    choices=sorted(REGISTRY))
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--full", action="store_true",
                    help="use the full published config (TPU-scale)")
    ap.add_argument("--d-model", type=int, default=None,
                    help="override smoke width (e.g. ~100M model)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if not args.full:
        cfg = smoke(cfg)
        if args.d_model:
            import dataclasses
            hd = max(16, args.d_model // max(cfg.n_heads, 1))
            cfg = dataclasses.replace(
                cfg, d_model=args.d_model, d_ff=args.d_model * 3,
                d_head=hd, vocab_size=4096,
                n_layers=max(cfg.n_layers, 8))
    shape = ShapeConfig("cli", args.seq, args.batch, "train")
    model = build_model(cfg)
    base = default_options_for(cfg)
    import dataclasses
    opts = dataclasses.replace(base, peak_lr=args.lr, warmup=10,
                               total_steps=args.steps, chunk=1024)
    state = init_train_state(model, jax.random.PRNGKey(args.seed), opts)
    step = build_train_step(model, opts)
    pipe = SyntheticPipeline(cfg, shape, seed=args.seed)
    tr = Trainer(model=model, train_step=step, pipeline=pipe, state=state,
                 ckpt_dir=args.ckpt_dir, ckpt_interval=50)
    tr.instantiate()
    res = tr.run(args.steps)
    print(tr.stats.dump_text())
    h = res["history"]
    print(json.dumps({"first_loss": h[0]["loss"], "last_loss": h[-1]["loss"],
                      "steps": res["final_step"],
                      "median_step_s": tr.watchdog.median()}, indent=1))


if __name__ == "__main__":
    main()
