"""Serving launcher: continuous-batching server on a (smoke) model.

``python -m repro.launch.serve --arch whisper-small --requests 8``
"""

from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import REGISTRY, get_config, smoke
from repro.models import build_model
from repro.serve import BatchServer, Request


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-1.6b",
                    choices=sorted(REGISTRY))
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=3)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = smoke(get_config(args.arch))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    srv = BatchServer(model=model, params=params, slots=args.slots,
                      seq_capacity=64)
    srv.instantiate()
    rng = np.random.default_rng(args.seed)
    reqs = []
    for i in range(args.requests):
        plen = int(rng.integers(2, 12))
        extras = {}
        if cfg.family == "vlm":
            extras["vision_embeds"] = rng.standard_normal(
                (cfg.n_vis, cfg.d_model)).astype(np.float32) * 0.1
        if cfg.family == "audio":
            extras["enc_embeds"] = rng.standard_normal(
                (cfg.enc_seq, cfg.d_model)).astype(np.float32) * 0.1
        reqs.append(Request(
            rid=i, prompt=rng.integers(0, cfg.vocab_size, plen),
            max_new_tokens=args.max_new, extras=extras))
    done = srv.serve(reqs)
    for r in sorted(done, key=lambda r: r.rid):
        print(f"req {r.rid}: prompt_len={len(r.prompt)} -> {r.output}")
    print(srv.stats.dump_text())


if __name__ == "__main__":
    main()
