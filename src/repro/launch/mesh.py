"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so
importing this module never touches jax device state — required because
smoke tests must see 1 CPU device while the dry-run forces 512
placeholder devices via XLA_FLAGS before any jax import.

Mesh layout (TPU v5e):
  single pod : (16, 16)      axes ("data", "model")   = 256 chips
  multi-pod  : (2, 16, 16)   axes ("pod", "data", "model") = 512 chips

The "model" axis maps onto one torus dimension (TP + sequence-parallel
collectives stay on neighbor ICI links); "data" onto the other (FSDP
all-gather / gradient reduce-scatter); "pod" crosses the DCN (gradient
all-reduce of the pod-local reduce-scatter result — the dist-gem5
hierarchical layering).
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax


def _mk(shape: Tuple[int, ...], axes: Tuple[str, ...]) -> jax.sharding.Mesh:
    # axis_types only exists on newer jax; Auto is the default there, so
    # omitting it on older versions is behaviourally identical.
    if hasattr(jax.sharding, "AxisType"):
        return jax.make_mesh(
            shape, axes,
            axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mk(shape, axes)


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]
              ) -> jax.sharding.Mesh:
    """Arbitrary mesh (DSE sweeps / tests on few host devices)."""
    return _mk(shape, axes)


def single_device_mesh() -> jax.sharding.Mesh:
    """1-device mesh with the production axis names (smoke tests)."""
    return make_mesh((1, 1), ("data", "model"))


def describe(mesh: jax.sharding.Mesh) -> dict:
    return {"axes": dict(zip(mesh.axis_names, mesh.devices.shape)),
            "devices": int(mesh.devices.size)}
