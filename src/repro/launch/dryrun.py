import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import (including
# repro.*, which imports jax): jax locks the device count on first init.

"""Multi-pod dry-run: lower + compile every (architecture x input shape)
cell on the production meshes and extract the roofline inputs.

For each cell this driver:
  1. builds the jitted step (train_step / prefill_step / decode_step)
     with full production shardings,
  2. ``.lower(**input_specs).compile()`` — success proves the sharding
     config is coherent (no mismatched specs, no unsupported
     collectives, memory fits),
  3. records ``compiled.memory_analysis()`` / ``cost_analysis()`` plus
     the loop-corrected FLOPs/bytes/collective-bytes from
     ``repro.core.desim.hlo_cost`` (XLA's cost_analysis counts scan
     bodies once — see that module's docstring),
  4. derives the three roofline terms (TPU v5e constants) and the
     collective schedule, and dumps JSON for EXPERIMENTS.md.

Usage:
  python -m repro.launch.dryrun --arch olmoe-1b-7b --shape train_4k
  python -m repro.launch.dryrun --all            # 40 cells x 2 meshes
  python -m repro.launch.dryrun --all --single-pod-only
"""

import argparse
import json
import time
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs import (REGISTRY, SHAPES, cell_runnable, get_config,
                           get_shape)
from repro.core.desim.hlo_cost import analyze_hlo
from repro.dist.sharding import MeshSharder, make_rules
from repro.launch.mesh import describe, make_production_mesh
from repro.models import build_model
from repro.serve.step import build_decode_step, build_prefill_step
from repro.train.step import (TrainOptions, build_train_step,
                              default_options_for, train_state_specs)

# TPU v5e per-chip constants (assignment-specified)
PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9
HBM_BYTES = 16e9

# Gradient-accumulation microbatching per train cell: chosen as the
# smallest power of two whose activation temp fits 16 GB HBM (measured
# via the dry-run memory_analysis — see EXPERIMENTS.md §Perf memory
# iterations).  Microbatching also enables compute/reduce-scatter
# overlap across microbatches.
TRAIN_ACCUM = {
    "deepseek-67b": 8,
    "jamba-v0.1-52b": 8,
    "mixtral-8x22b": 16,
    "olmoe-1b-7b": 4,
    "nemotron-4-15b": 2,
    "rwkv6-7b": 2,
    "stablelm-1.6b": 1,
    "minicpm-2b": 1,
    "qwen2-vl-7b": 1,
    "whisper-small": 1,
}


def roofline_terms(cost, n_dev: int) -> Dict[str, Any]:
    compute = cost.flops / PEAK_FLOPS
    memory = cost.bytes / HBM_BW
    # TPU-target variant: pure copy traffic (CPU while-carry copies)
    # is aliased away by TPU buffer assignment
    memory_ex_copies = max(0.0, (cost.bytes - cost.copy_bytes)) / HBM_BW
    coll = cost.collective_bytes / ICI_BW
    dom = max(("compute", compute), ("memory", memory),
              ("collective", coll), key=lambda kv: kv[1])[0]
    return {"compute_s": compute, "memory_s": memory,
            "memory_s_ex_copies": memory_ex_copies, "collective_s": coll,
            "dominant": dom, "bound_s": max(compute, memory, coll),
            "bound_s_ex_copies": max(compute, memory_ex_copies, coll),
            "hlo_flops_per_device": cost.flops,
            "hlo_bytes_per_device": cost.bytes,
            "copy_bytes_per_device": cost.copy_bytes,
            "collective_bytes_per_device": cost.collective_bytes}


def dryrun_cell(arch: str, shape_name: str, multi_pod: bool = False,
                opts: Optional[TrainOptions] = None,
                rules_override: Optional[Dict] = None,
                mesh=None, serve_param_dtype=None) -> Dict[str, Any]:
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    ok, why = cell_runnable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name,
                "mesh": "multi" if multi_pod else "single",
                "status": "skipped", "why": why}

    mesh = mesh or make_production_mesh(multi_pod=multi_pod)
    n_dev = int(mesh.devices.size)
    rules = make_rules(cfg, shape, mesh)
    if rules_override:
        rules.mapping.update(rules_override)
    sharder = MeshSharder(mesh, rules)
    model = build_model(cfg)
    if opts is None:
        import dataclasses
        import numpy as _np
        sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        dp = int(_np.prod([sizes.get(a, 1) for a in ("pod", "data")]))
        accum = TRAIN_ACCUM.get(arch, 1) if shape.kind == "train" else 1
        # microbatch must stay divisible by the data-parallel ranks
        accum = max(1, min(accum, shape.global_batch // dp))
        opts = dataclasses.replace(
            default_options_for(cfg), accum_steps=accum,
            moment_dtype=("bfloat16" if arch in
                          ("mixtral-8x22b", "jamba-v0.1-52b")
                          else "float32"),
            # adopted hillclimb (cell 2): train_4k fits one KV chunk ->
            # no online-softmax carry traffic (-12% memory term)
            chunk=(4096 if arch == "deepseek-67b"
                   and shape.kind == "train" else 2048))

    t0 = time.perf_counter()
    if shape.kind == "train":
        state_shapes, state_axes = train_state_specs(model, opts)
        step = build_train_step(model, opts, sharder,
                                param_axes=state_axes["params"])
        state_sh = sharder.param_shardings(state_axes)
        batch_specs = model.input_specs(shape)
        batch_sh = sharder.batch_shardings(batch_specs, cfg)
        fn = jax.jit(step, in_shardings=(state_sh, batch_sh),
                     donate_argnums=(0,))
        args = (state_shapes, batch_specs)
    elif shape.kind == "prefill":
        pstep = build_prefill_step(model, sharder, chunk=opts.chunk,
                                   seq_capacity=shape.seq_len)
        p_shapes, p_axes = model.param_specs()
        if serve_param_dtype is not None:
            p_shapes = jax.tree.map(
                lambda x: jax.ShapeDtypeStruct(x.shape, serve_param_dtype)
                if jnp.issubdtype(x.dtype, jnp.floating) else x, p_shapes)
        p_sh = sharder.param_shardings(p_axes)
        batch_specs = model.input_specs(shape)
        batch_sh = sharder.batch_shardings(batch_specs, cfg)
        fn = jax.jit(pstep, in_shardings=(p_sh, batch_sh))
        args = (p_shapes, batch_specs)
    else:  # decode
        dstep = build_decode_step(model, sharder)
        p_shapes, p_axes = model.param_specs()
        if serve_param_dtype is not None:
            p_shapes = jax.tree.map(
                lambda x: jax.ShapeDtypeStruct(x.shape, serve_param_dtype)
                if jnp.issubdtype(x.dtype, jnp.floating) else x, p_shapes)
        p_sh = sharder.param_shardings(p_axes)
        batch_specs = model.input_specs(shape)
        batch_sh = sharder.batch_shardings(batch_specs, cfg)
        fn = jax.jit(dstep, in_shardings=(p_sh, batch_sh),
                     donate_argnums=(1,))
        args = (p_shapes, batch_specs)

    with mesh:
        lowered = fn.lower(*args)
        t_lower = time.perf_counter() - t0
        t0 = time.perf_counter()
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t0

    ma = compiled.memory_analysis()
    mem = {
        "argument_bytes": float(getattr(ma, "argument_size_in_bytes", 0)),
        "output_bytes": float(getattr(ma, "output_size_in_bytes", 0)),
        "temp_bytes": float(getattr(ma, "temp_size_in_bytes", 0)),
        "alias_bytes": float(getattr(ma, "alias_size_in_bytes", 0)),
    }
    mem["per_device_total"] = (mem["argument_bytes"] + mem["output_bytes"]
                               + mem["temp_bytes"] - mem["alias_bytes"])
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    hlo = compiled.as_text()
    cost = analyze_hlo(hlo)
    rt = roofline_terms(cost, n_dev)

    # useful-FLOPs ratio: MODEL_FLOPS = 6 N D (train) or 2 N D (fwd)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        model_flops = cfg.model_flops(tokens, backward=True)
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        model_flops = cfg.model_flops(tokens, backward=False)
    else:
        tokens = shape.global_batch            # one new token per sequence
        model_flops = cfg.model_flops(tokens, backward=False)
    hlo_flops_global = cost.flops * n_dev
    result = {
        "arch": arch, "shape": shape_name,
        "mesh": "multi" if multi_pod else "single",
        "mesh_desc": describe(mesh),
        "status": "ok",
        "kind": shape.kind,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory": mem,
        "fits_hbm": mem["per_device_total"] <= HBM_BYTES,
        "xla_cost_analysis": {"flops": float(ca.get("flops", 0.0)),
                              "bytes": float(ca.get("bytes accessed", 0.0))},
        "roofline": rt,
        "collectives": cost.collectives,
        "model_flops_global": model_flops,
        "hlo_flops_global": hlo_flops_global,
        "useful_flops_ratio": (model_flops / hlo_flops_global
                               if hlo_flops_global else 0.0),
        "top_dots": [[f, n] for f, n in cost.top_dots[:5]],
        "top_bytes": [[b, n] for b, n in cost.top_bytes[:8]],
        "rules": rules.describe(),
    }
    return result


def run_matrix(single_pod_only: bool = False, out_dir: str = "results/dryrun",
               archs=None, shapes=None) -> None:
    os.makedirs(out_dir, exist_ok=True)
    meshes = [False] if single_pod_only else [False, True]
    archs = archs or sorted(REGISTRY)
    shapes = shapes or list(SHAPES)
    rows = []
    for multi in meshes:
        mesh = make_production_mesh(multi_pod=multi)
        for arch in archs:
            for shape in shapes:
                tag = f"{arch}__{shape}__{'multi' if multi else 'single'}"
                try:
                    res = dryrun_cell(arch, shape, multi, mesh=mesh)
                except Exception as e:  # a failure here is a sharding bug
                    res = {"arch": arch, "shape": shape,
                           "mesh": "multi" if multi else "single",
                           "status": "FAILED", "error": repr(e)[:500]}
                with open(os.path.join(out_dir, tag + ".json"), "w") as f:
                    json.dump(res, f, indent=1)
                rows.append(res)
                if res["status"] == "ok":
                    r = res["roofline"]
                    print(f"{tag:55s} ok  compile={res['compile_s']:6.1f}s "
                          f"mem={res['memory']['per_device_total']/1e9:6.2f}GB "
                          f"dom={r['dominant']:10s} bound={r['bound_s']:9.4f}s "
                          f"useful={res['useful_flops_ratio']:.2f}",
                          flush=True)
                else:
                    print(f"{tag:55s} {res['status']}: "
                          f"{res.get('why', res.get('error', ''))[:110]}",
                          flush=True)
    n_ok = sum(r["status"] == "ok" for r in rows)
    n_skip = sum(r["status"] == "skipped" for r in rows)
    n_fail = sum(r["status"] == "FAILED" for r in rows)
    print(f"\n== dry-run matrix: {n_ok} ok / {n_skip} skipped "
          f"(documented) / {n_fail} FAILED ==")
    with open(os.path.join(out_dir, "summary.json"), "w") as f:
        json.dump(rows, f, indent=1)
    if n_fail:
        raise SystemExit(1)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(REGISTRY))
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--single-pod-only", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    args = ap.parse_args()
    if args.all:
        run_matrix(args.single_pod_only, args.out)
        return
    if not args.arch or not args.shape:
        ap.error("--arch/--shape required unless --all")
    res = dryrun_cell(args.arch, args.shape, args.multi_pod)
    print(json.dumps(res, indent=1))


if __name__ == "__main__":
    main()
