"""Sharded, atomic, async checkpointing with resharding restore.

The gem5 checkpoint/restore pillar (§1.3, §2.7, §2.12.1) applied to
training state:

* **Atomic**: state is serialized into ``<dir>/step_K.tmp`` and renamed
  to ``<dir>/step_K`` only when complete — a crash mid-save can never
  corrupt the latest checkpoint (gem5's drain-then-serialize rule).
* **Async**: serialization runs on a background thread; ``save()``
  returns after snapshotting device arrays to host (the jax.device_get
  is the only synchronous part).  ``wait()`` joins before exit / next
  save.
* **Sharded layout**: one ``.npy`` per pytree leaf, keyed by the flat
  path, plus a JSON manifest (shapes, dtypes, step, keep-N policy).
* **Resharding restore**: ``restore(..., shardings=...)`` device_puts
  each leaf with *new* shardings — a checkpoint written on any mesh
  restores onto any other mesh (elastic re-mesh after failures).
* **keep_n**: old checkpoints are pruned (never the newest).
"""

from __future__ import annotations

import json
import os
import re
import shutil
import threading
import time
from typing import Any, Dict, List, Optional

import jax
import numpy as np


def _flatten(tree: Any) -> Dict[str, Any]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out[key] = leaf
    return out


class CheckpointManager:
    def __init__(self, directory: str, keep_n: int = 3, async_save: bool = True):
        self.dir = directory
        self.keep_n = keep_n
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        self._exc: Optional[BaseException] = None
        self.saves = 0
        self.save_seconds = 0.0
        os.makedirs(directory, exist_ok=True)

    # -- save ---------------------------------------------------------------
    def save(self, state: Any, step: int, extra: Optional[Dict] = None
             ) -> str:
        self.wait()
        host_state = jax.device_get(state)    # snapshot (sync, cheap on CPU)
        treedef = jax.tree.structure(state)
        final = os.path.join(self.dir, f"step_{step:08d}")

        def _write():
            t0 = time.perf_counter()
            tmp = final + ".tmp"
            if os.path.exists(tmp):
                shutil.rmtree(tmp)
            os.makedirs(tmp)
            flat = _flatten(host_state)
            manifest = {"step": step, "leaves": {}, "extra": extra or {},
                        "treedef": str(treedef)}
            for key, leaf in flat.items():
                arr = np.asarray(leaf)
                fname = key.replace("/", "__") + ".npy"
                np.save(os.path.join(tmp, fname), arr)
                manifest["leaves"][key] = {
                    "file": fname, "shape": list(arr.shape),
                    "dtype": str(arr.dtype)}
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)              # atomic publish
            self._prune()
            self.saves += 1
            self.save_seconds += time.perf_counter() - t0

        if self.async_save:
            def _write_async():
                # a failed background save must not be silent: park the
                # exception and re-raise it on the next wait()/save()
                try:
                    _write()
                except BaseException as e:     # noqa: BLE001
                    self._exc = e
            self._thread = threading.Thread(target=_write_async,
                                            daemon=True)
            self._thread.start()
        else:
            _write()
        return final

    def wait(self) -> None:
        """Join the in-flight async save.  If it failed, the exception
        is re-raised HERE (a silently-lost checkpoint would surface
        only at restore time, after the data is already gone)."""
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._exc is not None:
            exc, self._exc = self._exc, None
            raise exc

    def _prune(self) -> None:
        steps = self.available_steps()
        for s in steps[:-self.keep_n] if self.keep_n else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)

    # -- restore ------------------------------------------------------------
    def available_steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.dir):
            m = re.fullmatch(r"step_(\d+)", name)
            if m and os.path.exists(os.path.join(self.dir, name,
                                                 "manifest.json")):
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.available_steps()
        return steps[-1] if steps else None

    def restore(self, target: Any, step: Optional[int] = None,
                shardings: Any = None) -> Any:
        """Restore into the structure of ``target`` (a pytree of arrays or
        ShapeDtypeStructs).  ``shardings``: optional matching pytree of
        NamedShardings for resharded placement on a (new) mesh."""
        self.wait()
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        d = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)

        paths, treedef = jax.tree_util.tree_flatten_with_path(target)
        shard_flat = (jax.tree.leaves(shardings)
                      if shardings is not None else [None] * len(paths))
        leaves = []
        for (path, leaf), shard in zip(paths, shard_flat):
            key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                           for p in path)
            info = manifest["leaves"][key]
            arr = np.load(os.path.join(d, info["file"]))
            want_dtype = getattr(leaf, "dtype", arr.dtype)
            arr = arr.astype(want_dtype)
            if shard is not None:
                leaves.append(jax.device_put(arr, shard))
            else:
                leaves.append(jax.numpy.asarray(arr))
        return jax.tree_util.tree_unflatten(treedef, leaves)
