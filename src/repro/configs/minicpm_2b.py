"""MiniCPM-2B: llama-like dense decoder trained with the WSD schedule
[arXiv:2404.06395; hf].

The WSD (warmup-stable-decay) learning-rate schedule is the
paper-specific training feature; it is implemented in
``repro.optim.schedule.wsd_schedule`` and selected by this config.
MiniCPM ties input/output embeddings and scales residual branches by
1.4/sqrt(n_layers) (mu-p inspired depth scaling).
"""

import math

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="minicpm-2b",
    family="dense",
    source="arXiv:2404.06395; hf:openbmb/MiniCPM-2B-sft-bf16",
    n_layers=40,
    d_model=2304,
    n_heads=36,
    n_kv_heads=36,
    d_head=64,
    d_ff=5760,
    vocab_size=122753,
    act="swiglu",
    norm="rmsnorm",
    rope_theta=10000.0,
    tie_embeddings=True,
    residual_scale=1.4 / math.sqrt(40),
)
