"""Architecture + run configuration (the gem5 'known-good configs' layer).

gem5-20 §2.1 introduces *gem5 resources*: curated, versioned, known-good
configurations so researchers start from a common reproducible point.
``repro.configs`` is the analogue: every assigned architecture from the
public literature is one file exporting an exact ``ArchConfig``; the
registry resolves ``--arch <id>``; ``smoke()`` derives the reduced
config used by CPU tests (same family traits, tiny dims).

All configs are plain frozen dataclasses (hashable -> usable as jit
static args); the SimObject wrapper in ``repro.core.simobject`` can lift
them into the configuration tree for stats/describe.
"""

from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field, replace
from typing import Dict, Optional, Tuple


@dataclass(frozen=True)
class ArchConfig:
    """One model architecture, exactly as published."""

    name: str
    family: str                 # dense | moe | ssm | hybrid | vlm | audio
    source: str                 # arXiv / hf citation string

    n_layers: int               # decoder layers
    d_model: int
    n_heads: int                # query heads (0 = attention-free)
    n_kv_heads: int             # GQA kv heads
    d_ff: int                   # per-expert d_ff for MoE archs
    vocab_size: int

    d_head: int = 0             # 0 -> d_model // n_heads

    # --- MoE ----------------------------------------------------------
    n_experts: int = 0
    top_k: int = 0
    moe_every: int = 1          # MoE FFN on layers where l % moe_every == moe_offset
    moe_offset: int = 0
    capacity_factor: float = 1.25

    # --- attention flavour ---------------------------------------------
    rope_theta: float = 10000.0
    rope_pct: float = 1.0       # partial rotary (stablelm = 0.25)
    pos_scheme: str = "rope"    # rope | mrope | learned | none
    sliding_window: int = 0     # 0 = full attention
    qk_norm: bool = False

    # --- FFN / norm -----------------------------------------------------
    act: str = "swiglu"         # swiglu | gelu | sq_relu
    norm: str = "rmsnorm"       # rmsnorm | layernorm
    norm_eps: float = 1e-5

    # --- SSM (mamba / rwkv) ----------------------------------------------
    d_state: int = 16           # mamba state per channel
    d_conv: int = 4             # mamba local conv taps
    expand: int = 2             # mamba d_inner = expand * d_model
    rwkv_head_size: int = 64

    # --- hybrid (jamba) ---------------------------------------------------
    attn_every: int = 0         # one attention layer per `attn_every` (else mamba)
    attn_offset: int = 0

    # --- encoder-decoder (whisper) / vlm (qwen2-vl) -----------------------
    enc_layers: int = 0
    enc_seq: int = 0            # fixed encoder frames (whisper: 1500)
    n_vis: int = 0              # vlm stub patch embeddings prepended

    tie_embeddings: bool = False
    residual_scale: float = 1.0  # minicpm depth-scaled residual

    # ----------------------------------------------------------------------
    @property
    def head_dim(self) -> int:
        if self.d_head:
            return self.d_head
        return self.d_model // max(self.n_heads, 1)

    @property
    def is_attention_free(self) -> bool:
        return self.n_heads == 0

    @property
    def n_rwkv_heads(self) -> int:
        return self.d_model // self.rwkv_head_size

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    def is_moe_layer(self, layer: int) -> bool:
        if self.n_experts == 0:
            return False
        return layer % self.moe_every == self.moe_offset

    def is_attn_layer(self, layer: int) -> bool:
        """hybrid archs: which decoder layers are attention (vs mamba)."""
        if self.family != "hybrid":
            return not self.is_attention_free
        return self.attn_every > 0 and layer % self.attn_every == self.attn_offset

    # -- parameter counts (for MODEL_FLOPS = 6 N D) ------------------------
    def param_counts(self) -> Dict[str, float]:
        d, f = self.d_model, self.d_ff
        hd = self.head_dim
        counts: Dict[str, float] = {}
        counts["embed"] = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        attn_layer = d * (self.n_heads * hd) + 2 * d * (self.n_kv_heads * hd) \
            + (self.n_heads * hd) * d
        ffn_mats = 3 if self.act == "swiglu" else 2
        dense_ffn = ffn_mats * d * f
        moe_ffn = self.n_experts * ffn_mats * d * f + d * self.n_experts
        mamba_layer = (d * 2 * self.d_inner            # in_proj
                       + self.d_inner * self.d_conv    # conv
                       + self.d_inner * (self.d_state * 2 + 1)  # x_proj-ish
                       + self.d_inner                  # dt
                       + self.d_inner * self.d_state   # A
                       + self.d_inner * d)             # out_proj
        rwkv_layer = 6 * d * d + 3 * d * 32            # r,k,v,g,o,ffn-ish lora
        total = counts["embed"]
        active = counts["embed"]
        for layer in range(self.n_layers):
            if self.family == "ssm":
                lp = rwkv_layer + 2 * d * f  # rwkv channel-mix (2 mats)
                la = lp
            else:
                mixer = attn_layer if self.is_attn_layer(layer) else mamba_layer
                if self.is_moe_layer(layer):
                    lp = mixer + moe_ffn
                    la = mixer + self.top_k * ffn_mats * d * f + d * self.n_experts
                else:
                    lp = mixer + dense_ffn
                    la = lp
            total += lp
            active += la
        enc_attn = 4 * d * d
        total += self.enc_layers * (enc_attn + 2 * d * f)
        active += self.enc_layers * (enc_attn + 2 * d * f)
        counts["total"] = float(total)
        counts["active"] = float(active)
        return counts

    def model_flops(self, tokens: float, backward: bool = True) -> float:
        """6 * N_active * D (2ND forward, 4ND backward)."""
        n = self.param_counts()["active"]
        mult = 6.0 if backward else 2.0
        return mult * n * tokens


# ---------------------------------------------------------------------------
# Input shapes (assigned shape set; identical for every LM arch)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                   # train | prefill | decode


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}

# archs with a sub-quadratic decode path (everything else skips long_500k)
SUBQUADRATIC = ("mixtral-8x22b", "rwkv6-7b", "jamba-v0.1-52b")


def cell_runnable(arch: "ArchConfig", shape: ShapeConfig) -> Tuple[bool, str]:
    """Is (arch x shape) a runnable dry-run cell?  (False, why) if skipped."""
    if shape.name == "long_500k" and arch.name not in SUBQUADRATIC:
        return False, ("pure full-attention arch: O(S^2)/full-KV decode at "
                       "524288 is out of scope per assignment (documented in "
                       "DESIGN.md long_500k skip list)")
    return True, ""


# ---------------------------------------------------------------------------
# Smoke reduction
# ---------------------------------------------------------------------------

def smoke(cfg: ArchConfig) -> ArchConfig:
    """Reduced same-family config for CPU smoke tests."""
    changes: Dict[str, object] = dict(
        n_layers=min(cfg.n_layers, 4),
        d_model=64,
        d_ff=128,
        vocab_size=256,
        d_head=16,
        enc_seq=min(cfg.enc_seq, 16) if cfg.enc_seq else 0,
        enc_layers=min(cfg.enc_layers, 2),
        n_vis=4 if cfg.n_vis else 0,
        rwkv_head_size=16,
        sliding_window=min(cfg.sliding_window, 8) if cfg.sliding_window else 0,
    )
    if cfg.n_heads:
        changes["n_heads"] = 4
        changes["n_kv_heads"] = max(1, round(4 * cfg.n_kv_heads / cfg.n_heads))
    if cfg.n_experts:
        changes["n_experts"] = 4
        changes["top_k"] = min(cfg.top_k, 2)
    if cfg.family == "hybrid":
        changes["n_layers"] = max(cfg.attn_every, 4)
    return replace(cfg, **changes)


def smoke_shape(kind: str = "train") -> ShapeConfig:
    return {
        "train": ShapeConfig("smoke_train", 32, 4, "train"),
        "prefill": ShapeConfig("smoke_prefill", 32, 2, "prefill"),
        "decode": ShapeConfig("smoke_decode", 32, 4, "decode"),
    }[kind]
