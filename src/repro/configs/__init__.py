"""Architecture registry (``--arch <id>`` resolution).

Mirrors gem5-resources' "known-good configurations": each module in this
package exports one ``CONFIG`` with the exact published numbers.
"""

from __future__ import annotations

from typing import Dict, List

from repro.configs.base import (  # noqa: F401
    ArchConfig, ShapeConfig, SHAPES, SUBQUADRATIC, cell_runnable, smoke,
    smoke_shape,
)

from repro.configs.olmoe_1b_7b import CONFIG as _olmoe
from repro.configs.mixtral_8x22b import CONFIG as _mixtral
from repro.configs.stablelm_1_6b import CONFIG as _stablelm
from repro.configs.deepseek_67b import CONFIG as _deepseek
from repro.configs.minicpm_2b import CONFIG as _minicpm
from repro.configs.nemotron_4_15b import CONFIG as _nemotron
from repro.configs.qwen2_vl_7b import CONFIG as _qwen2vl
from repro.configs.rwkv6_7b import CONFIG as _rwkv6
from repro.configs.jamba_v0_1_52b import CONFIG as _jamba
from repro.configs.whisper_small import CONFIG as _whisper

REGISTRY: Dict[str, ArchConfig] = {
    c.name: c for c in (
        _olmoe, _mixtral, _stablelm, _deepseek, _minicpm, _nemotron,
        _qwen2vl, _rwkv6, _jamba, _whisper,
    )
}


def get_config(name: str) -> ArchConfig:
    try:
        return REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown arch {name!r}; one of {sorted(REGISTRY)}") from None


def all_archs() -> List[str]:
    return sorted(REGISTRY)


def get_shape(name: str) -> ShapeConfig:
    try:
        return SHAPES[name]
    except KeyError:
        raise KeyError(
            f"unknown shape {name!r}; one of {sorted(SHAPES)}") from None
