"""Jamba-v0.1 (52B): hybrid Mamba + attention (1:7) with 16-expert top-2
MoE on alternate layers [arXiv:2403.19887; hf].

Layer pattern (period 8, as published): attention at layer index 4 of
each 8-layer block, Mamba elsewhere; MoE FFN every other layer.
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    source="arXiv:2403.19887; hf:ai21labs/Jamba-v0.1",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_head=128,
    d_ff=14336,
    vocab_size=65536,
    n_experts=16,
    top_k=2,
    capacity_factor=1.0,     # system knob (not an arch param): fits HBM
    moe_every=2,
    moe_offset=1,
    attn_every=8,
    attn_offset=4,
    d_state=16,
    d_conv=4,
    expand=2,
    act="swiglu",
    norm="rmsnorm",
    pos_scheme="none",         # jamba uses no positional encoding
)
