"""Qwen2-VL-7B backbone: M-RoPE (3-D rotary sections), dynamic-resolution
vision [arXiv:2409.12191; hf].

Per the assignment, ``[vlm]`` entries specify the transformer BACKBONE
only; the ViT/patch-embedding frontend is a STUB — ``input_specs()``
provides precomputed patch embeddings of shape (batch, n_vis, d_model)
that the model merges in front of the text tokens, and 3-D (t/h/w)
M-RoPE position ids for the merged sequence.
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-vl-7b",
    family="vlm",
    source="arXiv:2409.12191; hf:Qwen/Qwen2-VL-7B-Instruct",
    n_layers=28,
    d_model=3584,
    n_heads=28,
    n_kv_heads=4,
    d_head=128,
    d_ff=18944,
    vocab_size=152064,
    pos_scheme="mrope",
    act="swiglu",
    norm="rmsnorm",
    rope_theta=1000000.0,
    n_vis=256,
)
