"""Whisper-small: encoder-decoder audio transformer [arXiv:2212.04356;
unverified].

Per the assignment the conv/mel frontend is a STUB: ``input_specs()``
provides precomputed encoder frame embeddings (batch, 1500, d_model);
the 12-layer encoder runs full self-attention over them and the
12-layer decoder adds cross-attention.  Decode shapes run (it has a
decoder); ``train_4k`` trains the decoder at seq_len with the encoder
at its fixed 1500 frames.
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-small",
    family="audio",
    source="arXiv:2212.04356; hf:openai/whisper-small (unverified tier)",
    n_layers=12,
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    d_head=64,
    d_ff=3072,
    vocab_size=51865,
    enc_layers=12,
    enc_seq=1500,
    pos_scheme="learned",
    act="gelu",
    norm="layernorm",
)
