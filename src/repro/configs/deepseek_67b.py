"""DeepSeek-67B: deep llama-arch dense decoder [arXiv:2401.02954; hf]."""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-67b",
    family="dense",
    source="arXiv:2401.02954; hf:deepseek-ai/deepseek-llm-67b-base",
    n_layers=95,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_head=128,
    d_ff=22016,
    vocab_size=102400,
    act="swiglu",
    norm="rmsnorm",
    rope_theta=10000.0,
)
