"""RWKV-6 (Finch) 7B: attention-free, data-dependent-decay linear
recurrence [arXiv:2404.05892; hf]."""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="rwkv6-7b",
    family="ssm",
    source="arXiv:2404.05892; hf:RWKV/rwkv-6-world-7b",
    n_layers=32,
    d_model=4096,
    n_heads=0,                 # attention-free
    n_kv_heads=0,
    d_ff=14336,
    vocab_size=65536,
    rwkv_head_size=64,
    act="relu_sq_channelmix",  # rwkv channel-mix uses relu^2
    norm="layernorm",
    pos_scheme="none",
)
