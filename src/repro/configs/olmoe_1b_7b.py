"""OLMoE-1B-7B: 64-expert top-8 MoE [arXiv:2409.02060; hf]."""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="olmoe-1b-7b",
    family="moe",
    source="arXiv:2409.02060; hf:allenai/OLMoE-1B-7B-0924",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_head=128,
    d_ff=1024,
    vocab_size=50304,
    n_experts=64,
    top_k=8,
    qk_norm=True,
    act="swiglu",
    norm="rmsnorm",
    rope_theta=10000.0,
)
