"""Design-space exploration example — THE gem5 use case (paper §1):
describe a system once, sweep hardware/system parameters in the
discrete-event simulator, read the predicted step times.

Sweeps, for a stablelm-1.6b train step (costs taken from the real
dry-run artifact when present):
  * HBM bandwidth 0.5x..2x          (buy faster memory?)
  * ICI link bandwidth 0.5x..2x     (faster interconnect?)
  * collective algorithm            (ring vs torus vs hierarchical)
  * comm/compute overlap on/off     (software change!)

Run:  PYTHONPATH=src python examples/dse_explore.py
"""

import glob
import json

from repro.core.desim.collectives import ALGORITHMS
from repro.core.desim.trace import analytic_trace
from repro.sim import v5e_pod

art = glob.glob("results/dryrun/stablelm-1.6b__train_4k__single.json")
if art:
    d = json.load(open(art[0]))
    r = d["roofline"]
    L = 24
    flops, nbytes = r["hlo_flops_per_device"] / L, r["hlo_bytes_per_device"] / L
    coll = r["collective_bytes_per_device"] / L * 256
    src = "real dry-run artifact"
else:
    L, flops, nbytes, coll = 24, 2.4e12, 2.2e11, 1.3e11
    src = "analytic estimate"
print(f"workload: stablelm-1.6b train_4k ({src})")

rows = []
for hbm_mult in (0.5, 1.0, 2.0):
    for ici_mult in (0.5, 1.0, 2.0):
        for alg in ALGORITHMS:
            for overlap in (False, True):
                # prebuilt board with per-component overrides: no
                # hand-wired ClusterModel (repro.sim.boards)
                board = v5e_pod(chip={"hbm_bw": 819e9 * hbm_mult},
                                ici={"bw": 50e9 * ici_mult},
                                algorithm=alg)
                tr = analytic_trace(
                    "w", L, flops, nbytes,
                    [{"kind": "all-reduce", "bytes": coll,
                      "participants": 256}], overlap=overlap)
                t = board.executor().execute(tr).makespan_s
                rows.append((t, hbm_mult, ici_mult, alg, overlap))

rows.sort()
print(f"{len(rows)} configurations simulated")
print("best 5:")
for t, hbm, ici, alg, ovl in rows[:5]:
    print(f"  {t:8.4f}s  hbm x{hbm} ici x{ici} alg={alg:12s} overlap={ovl}")
print("worst:")
t, hbm, ici, alg, ovl = rows[-1]
print(f"  {t:8.4f}s  hbm x{hbm} ici x{ici} alg={alg:12s} overlap={ovl}")
base = [r for r in rows if r[1] == 1.0 and r[2] == 1.0][0]
print(f"\ninsight: best config is {rows[-1][0]/rows[0][0]:.1f}x faster than "
      f"worst; at nominal hardware the best software-only choice gives "
      f"{base[0]:.4f}s (alg={base[3]}, overlap={base[4]})")
print("dse_explore OK")
