"""Request-level serving simulation quickstart (and the CI smoke lap).

A short open-loop Poisson run of a 70B-class model on a v5e serving
slice: requests arrive as events, prefill/decode ops are injected into
the live DES run, KV slots contend, and TTFT/TPOT/latency percentiles
come out of the stats tree.  Asserts nonzero goodput and a coherent
stats dump, so ``tools/ci.sh smoke`` catches serving bit-rot.

  PYTHONPATH=src python examples/serve_sim.py
"""

from repro.sim import (ExitEventType, ServeSim, ServingCost, Simulator,
                       poisson_requests, v5e_serving)


def main() -> None:
    board = v5e_serving(8, 8)
    cost = ServingCost.from_params(70e9, layers=80, d_model=8192,
                                   chips=board.machine.num_chips)
    requests = poisson_requests(60, 40.0, seed=17,
                                prompt_len=(64, 512), decode_len=(16, 64))
    srv = ServeSim(cost=cost, requests=requests, slots=16,
                   seq_capacity=1024, slo_ttft_s=0.05, slo_latency_s=2.0)
    sim = Simulator(board, srv)

    events = list(sim.run())
    assert events[-1].kind is ExitEventType.DONE
    res = sim.result()
    s = srv.summary()

    print(f"board              : {board.name}")
    print(f"requests served    : {int(s['requests'])} "
          f"({int(s['tokens_out'])} tokens, "
          f"{int(srv.s_decode_steps.value())} decode steps)")
    print(f"simulated span     : {s['span_s'] * 1e3:.1f} ms "
          f"({res.events} engine events)")
    print(f"throughput/goodput : {s['throughput_rps']:.1f} / "
          f"{s['goodput_rps']:.1f} rps "
          f"({int(s['slo_violations'])} SLO violations)")
    print(f"TTFT p50/p99       : {s['p50_ttft_s'] * 1e3:.2f} / "
          f"{s['p99_ttft_s'] * 1e3:.2f} ms")
    print(f"latency p50/p99    : {s['p50_latency_s'] * 1e3:.1f} / "
          f"{s['p99_latency_s'] * 1e3:.1f} ms")
    print(f"mean TPOT          : {s['mean_tpot_s'] * 1e3:.3f} ms/token")
    print(f"mean decode batch  : {s['mean_batch']:.1f} of {srv.slots} slots")

    # smoke assertions (tools/ci.sh smoke)
    assert s["requests"] == 60, "all requests must complete"
    assert s["goodput_rps"] > 0, "goodput must be nonzero"
    flat = srv.stats.flat()
    assert flat["serve.requests_done"] == 60
    assert flat["serve.ttft"]["count"] == 60
    print("serving smoke OK")


if __name__ == "__main__":
    main()
