"""End-to-end driver (assignment deliverable b): train a ~100M-param
dense model for a few hundred steps with checkpointing, failure
recovery, and stats — the full production loop at CPU scale.

Run:  PYTHONPATH=src python examples/train_e2e.py [--steps 200]
"""

import argparse
import dataclasses
import os
import tempfile

import jax
import numpy as np

from repro.configs import get_config, smoke
from repro.configs.base import ShapeConfig
from repro.data import SyntheticPipeline
from repro.models import build_model
from repro.train import TrainOptions, build_train_step, init_train_state
from repro.train.trainer import SimulatedFailure, Trainer

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=200)
ap.add_argument("--dim", type=int, default=512)
args = ap.parse_args()

# ~100M params: 8 layers x d=512 (d_ff 1536) + 32k vocab
base = smoke(get_config("stablelm-1.6b"))
cfg = dataclasses.replace(
    base, n_layers=8, d_model=args.dim, d_ff=3 * args.dim, d_head=64,
    n_heads=args.dim // 64, n_kv_heads=args.dim // 64, vocab_size=32768)
model = build_model(cfg)
n_params = sum(int(np.prod(s.shape))
               for s in jax.tree.leaves(model.param_specs()[0]))
print(f"model: {cfg.n_layers}L d={cfg.d_model} params={n_params/1e6:.1f}M")

shape = ShapeConfig("e2e", seq_len=128, global_batch=8, kind="train")
opts = TrainOptions(peak_lr=3e-3, warmup=20, total_steps=args.steps,
                    chunk=128)
state = init_train_state(model, jax.random.PRNGKey(0), opts)
step = build_train_step(model, opts)
pipe = SyntheticPipeline(cfg, shape, seed=1)

with tempfile.TemporaryDirectory() as d:
    tr = Trainer(model=model, train_step=step, pipeline=pipe, state=state,
                 ckpt_dir=os.path.join(d, "ckpt"), ckpt_interval=50,
                 heartbeat_path=os.path.join(d, "hb.json"))
    tr.instantiate()
    # inject one failure mid-run: the trainer must restore and continue
    res = tr.run(args.steps,
                 fail_at={args.steps // 2: SimulatedFailure("injected")})
    h = res["history"]
    print(f"loss: {h[0]['loss']:.3f} -> {h[-1]['loss']:.3f} over "
          f"{res['final_step']} steps "
          f"(recovered {int(tr.s_failures.value())} failure)")
    assert h[-1]["loss"] < h[0]["loss"], "training must reduce loss"
    print(tr.stats.dump_text())
print("train_e2e OK")
