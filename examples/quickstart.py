"""Quickstart: the g5x workflow in one page.

1. pick an architecture config     (gem5: choose known-good config)
2. build the model + train step    (gem5: compose SimObjects in Python)
3. train a few steps for real      (gem5: KVM/native fidelity)
4. dry-run lower+compile           (gem5: atomic fidelity)
5. replay the compiled step on a
   parameterized TPU machine model (gem5: detailed/O3 fidelity)
6. script the simulation with the
   Simulator exit-event loop       (gem5 stdlib: boards + exit events,
                                    checkpoint / restore / re-sweep)

Run:  PYTHONPATH=src python examples/quickstart.py

Observability (PR 7, gem5 m5out/DPRINTF): add ``--trace-dir DIR`` to
dump a gem5-style output directory from step 6's simulation — stats.txt,
config.json, telemetry.json, and a Perfetto trace.json (open at
https://ui.perfetto.dev) — and ``--debug-flags Exec,Dcn`` (or ``All``)
to stream DPRINTF lines.  Both off by default; results are identical
either way.
"""

import argparse

import jax
import jax.numpy as jnp

from repro.configs import get_config, smoke
from repro.configs.base import ShapeConfig
from repro.core.desim.trace import analytic_trace
from repro.core.fidelity import DesimBackend, DryRunBackend, StepProgram
from repro.data import SyntheticPipeline
from repro.models import build_model
from repro.sim import (ExitEventType, Simulator, SteadyStateWorkload,
                       v5e_pod)
from repro.train import TrainOptions, build_train_step, init_train_state

ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
ap.add_argument("--trace-dir", default=None, metavar="DIR",
                help="write an m5out-style dir (stats.txt, config.json, "
                     "telemetry.json, Perfetto trace.json) for step 6")
ap.add_argument("--debug-flags", default=None, metavar="FLAGS",
                help="comma-separated DPRINTF flags (e.g. Exec,Dcn or All)")
cli = ap.parse_args()
if cli.debug_flags:
    from repro.sim import enable_debug_flags
    enable_debug_flags(cli.debug_flags)

# -- 1. config --------------------------------------------------------------
cfg = smoke(get_config("olmoe-1b-7b"))           # reduced MoE config
shape = ShapeConfig("quick", seq_len=32, global_batch=4, kind="train")
print(f"arch={cfg.name} layers={cfg.n_layers} experts={cfg.n_experts}")

# -- 2. model + step ---------------------------------------------------------
model = build_model(cfg)
opts = TrainOptions(peak_lr=5e-3, warmup=5, total_steps=30, chunk=16)
state = init_train_state(model, jax.random.PRNGKey(0), opts)
train_step = jax.jit(build_train_step(model, opts))

# -- 3. native fidelity: actually train --------------------------------------
pipe = SyntheticPipeline(cfg, shape)
for step_i in range(30):
    batch = {k: jnp.asarray(v) for k, v in pipe.batch(step_i).items()}
    state, metrics = train_step(state, batch)
    if step_i % 10 == 0:
        print(f"step {step_i:3d} loss={float(metrics['loss']):.3f} "
              f"aux={float(metrics['aux_loss']):.3f}")
print(f"final loss={float(metrics['loss']):.3f}")

# -- 4. dryrun fidelity: compiled-artifact analysis ---------------------------
prog = StepProgram(
    "quick_train", build_train_step(model, opts),
    (jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state),
     {k: jax.ShapeDtypeStruct(v.shape, jnp.asarray(v).dtype)
      for k, v in pipe.batch(0).items()}))
rep = DryRunBackend().run(prog)
print(f"dryrun: flops/step={rep.flops:.2e} hbm_bytes={rep.bytes_accessed:.2e}")

# -- 5. desim fidelity: predicted step time on a TPU machine model ------------
rep2 = DesimBackend(board=v5e_pod()).run(prog, dryrun_report=rep)
print(f"desim: predicted TPU-pod step time = {rep2.predicted_step_s:.3e} s")

# -- 6. the Simulator front-end: exit events + checkpoint/restore -------------
# a 16-step steady-state training workload with the dry-run's per-step
# costs spread over the layers (per-layer all-reduce: data-parallel grad
# sync when this step is sharded over the pod)
L = cfg.n_layers
step_trace = analytic_trace(
    "quick_step", L, (rep.flops or 0.0) / L, (rep.bytes_accessed or 0.0) / L,
    [{"kind": "all-reduce", "bytes": 2 * (rep.bytes_accessed or 0.0) / L,
      "participants": 256}])
sim = Simulator(v5e_pod(), SteadyStateWorkload(step_trace, 16),
                outdir=cli.trace_dir,
                trace_events=cli.trace_dir is not None,
                verbose=cli.trace_dir is not None)
per_step = v5e_pod().executor().execute(step_trace).makespan_s
mid = int(per_step * 1e9 * 4)                  # ticks are ns: 4 steps in
sim.schedule_max_tick(mid)                     # pause after ~4 steps...
sim.schedule_checkpoint(mid)                   # ...checkpoint there
for ev in sim.run():
    print(f"  exit event: {ev}")
    if ev.kind is ExitEventType.CHECKPOINT:
        ckpt = ev.payload["checkpoint"]
# restore the checkpoint onto a machine with doubled HBM bandwidth: the
# remaining 12 steps re-time under the new hardware (checkpoint once,
# sweep hardware — the gem5 DSE workflow)
fast = Simulator.from_checkpoint(ckpt, board=v5e_pod(
    chip={"hbm_bw": 2 * 819e9}))
res_fast = fast.run_to_completion()
print(f"simulator: 16-step nominal={sim.result().makespan_s:.3e}s "
      f"2xHBM-from-checkpoint={res_fast.makespan_s:.3e}s")
if cli.trace_dir:
    print(f"wrote m5out-style output dir: {cli.trace_dir}/"
          "{stats.txt,config.json,telemetry.json,trace.json}")
print("quickstart OK")
