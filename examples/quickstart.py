"""Quickstart: the g5x workflow in one page.

1. pick an architecture config     (gem5: choose known-good config)
2. build the model + train step    (gem5: compose SimObjects in Python)
3. train a few steps for real      (gem5: KVM/native fidelity)
4. dry-run lower+compile           (gem5: atomic fidelity)
5. replay the compiled step on a
   parameterized TPU machine model (gem5: detailed/O3 fidelity)

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp

from repro.configs import get_config, smoke
from repro.configs.base import ShapeConfig
from repro.core.fidelity import DesimBackend, DryRunBackend, StepProgram
from repro.data import SyntheticPipeline
from repro.models import build_model
from repro.train import TrainOptions, build_train_step, init_train_state

# -- 1. config --------------------------------------------------------------
cfg = smoke(get_config("olmoe-1b-7b"))           # reduced MoE config
shape = ShapeConfig("quick", seq_len=32, global_batch=4, kind="train")
print(f"arch={cfg.name} layers={cfg.n_layers} experts={cfg.n_experts}")

# -- 2. model + step ---------------------------------------------------------
model = build_model(cfg)
opts = TrainOptions(peak_lr=5e-3, warmup=5, total_steps=30, chunk=16)
state = init_train_state(model, jax.random.PRNGKey(0), opts)
train_step = jax.jit(build_train_step(model, opts))

# -- 3. native fidelity: actually train --------------------------------------
pipe = SyntheticPipeline(cfg, shape)
for step_i in range(30):
    batch = {k: jnp.asarray(v) for k, v in pipe.batch(step_i).items()}
    state, metrics = train_step(state, batch)
    if step_i % 10 == 0:
        print(f"step {step_i:3d} loss={float(metrics['loss']):.3f} "
              f"aux={float(metrics['aux_loss']):.3f}")
print(f"final loss={float(metrics['loss']):.3f}")

# -- 4. dryrun fidelity: compiled-artifact analysis ---------------------------
prog = StepProgram(
    "quick_train", build_train_step(model, opts),
    (jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state),
     {k: jax.ShapeDtypeStruct(v.shape, jnp.asarray(v).dtype)
      for k, v in pipe.batch(0).items()}))
rep = DryRunBackend().run(prog)
print(f"dryrun: flops/step={rep.flops:.2e} hbm_bytes={rep.bytes_accessed:.2e}")

# -- 5. desim fidelity: predicted step time on a TPU machine model ------------
rep2 = DesimBackend().run(prog, dryrun_report=rep)
print(f"desim: predicted TPU-pod step time = {rep2.predicted_step_s:.3e} s")
print("quickstart OK")
