"""Autoscaled fleet serving quickstart (and the CI fleet lap's demo).

A flash crowd hits a fleet of 70B-class continuous-batching replicas:
the least-loaded router spreads requests, the autoscaler reacts to
queue/SLO pressure by warming new replicas (cold start is a simulated
cost — work queues on a warming replica until its promotion), and
scale actions surface as SCALE_UP/SCALE_DOWN exit events.  The same
pure FleetPolicy then replays the recorded event feed through the real
FleetController and the decision logs are asserted identical — the
DES-vs-deployment fidelity claim, live.

  PYTHONPATH=src python examples/fleet_sim.py
"""

from repro.core.desim.simnodes import to_ticks
from repro.serve import FleetController, FleetPolicy
from repro.sim import (ExitEventType, FleetSim, ServingCost, Simulator,
                       flash_crowd_requests, v5e_fleet)


def mk_policy() -> FleetPolicy:
    return FleetPolicy("least_loaded", min_replicas=2, max_replicas=6,
                       slots_per_replica=8,
                       cold_start_ticks=to_ticks(1.0),
                       control_period_ticks=to_ticks(0.5), seed=7)


def main() -> None:
    board = v5e_fleet(max_replicas=6, nx=4, ny=4)
    cost = ServingCost.from_params(70e9, layers=80, d_model=8192, chips=16)
    requests = flash_crowd_requests(420, seed=7, base_rps=15.0,
                                    crowd_rps=90.0, crowd_start_s=2.0,
                                    crowd_len_s=3.0, prefix_groups=8)
    fleet = FleetSim(cost=cost, requests=requests, policy=mk_policy(),
                     seq_capacity=1024, slo_ttft_s=0.6, slo_latency_s=4.0,
                     tenant_slo={"batch": 4.0})
    sim = Simulator(board, fleet, timing="atomic")

    events = list(sim.run())
    assert events[-1].kind is ExitEventType.DONE
    for e in events:
        if e.kind in (ExitEventType.SCALE_UP, ExitEventType.SCALE_DOWN):
            print(f"t={e.tick / 1e9:7.3f}s  {e.cause}")

    s = fleet.summary()
    print(f"board              : {board.name}")
    print(f"requests served    : {int(s['requests'])} "
          f"({int(s['tokens_out'])} tokens)")
    print(f"simulated span     : {s['span_s']:.2f} s")
    print(f"throughput/goodput : {s['throughput_rps']:.1f} / "
          f"{s['goodput_rps']:.1f} rps "
          f"({int(s['slo_violations'])} SLO violations)")
    print(f"replicas           : peak {int(s['replicas_peak'])}, "
          f"final {int(s['replicas_final'])} "
          f"({int(s['scale_ups'])} up / {int(s['scale_downs'])} down, "
          f"{s['cold_start_s']:.1f}s cold start)")
    print(f"TTFT p50/p99       : {s['p50_ttft_s'] * 1e3:.1f} / "
          f"{s['p99_ttft_s'] * 1e3:.1f} ms")
    print(f"post-crowd SLO ok  : {fleet.slo_ok_frac(8.0):.2f} "
          "(requests submitted after t=8s)")

    # the identity claim, live: replay the recorded feed through the
    # real controller and compare decision logs bit for bit
    ctl = FleetController(mk_policy())
    ctl.replay(fleet.feed, requests)
    assert ctl.policy.decisions == fleet.policy.decisions
    print(f"controller replay  : {len(ctl.policy.decisions)} decisions, "
          "identical to the DES log")

    # smoke assertions (tools/ci.sh fleet)
    assert s["requests"] == 420, "all requests must complete"
    assert s["scale_ups"] >= 1, "the crowd must trigger a scale-up"
    assert fleet.slo_ok_frac(8.0) >= 0.9, "SLO must recover post-crowd"
    print("fleet smoke OK")


if __name__ == "__main__":
    main()
