"""Batched serving example (assignment deliverable b): continuous
batching over mixed-length requests on a small model, verifying the
batched outputs match sequential greedy decoding.

Run:  PYTHONPATH=src python examples/serve_batch.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, smoke
from repro.models import build_model
from repro.serve import BatchServer, Request

cfg = smoke(get_config("mixtral-8x22b"))      # MoE + sliding window
model = build_model(cfg)
params = model.init(jax.random.PRNGKey(0))

srv = BatchServer(model=model, params=params, slots=3, seq_capacity=48)
srv.instantiate()
rng = np.random.default_rng(0)
reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab_size,
                                           int(rng.integers(2, 10))),
                max_new_tokens=6) for i in range(7)]
done = srv.serve(reqs)

# verify against sequential decoding for one request
req = done[0]
logits, cache = jax.jit(lambda p, b: model.prefill(p, b, seq_capacity=48))(
    params, {"tokens": jnp.asarray(req.prompt[None])})
toks = [int(jnp.argmax(logits[0, -1].astype(jnp.float32)))]
cur = len(req.prompt)
for _ in range(len(req.output) - 1):
    logits, cache = jax.jit(
        lambda p, t, c, cl: model.decode(p, {"tokens": t}, c, cl))(
            params, jnp.asarray([[toks[-1]]]), cache,
            jnp.asarray(cur, jnp.int32))
    toks.append(int(jnp.argmax(logits[0, -1].astype(jnp.float32))))
    cur += 1
assert req.output == toks, (req.output, toks)
print(f"served {len(done)} requests, "
      f"{int(srv.s_tokens.value())} tokens, "
      f"{srv.s_throughput.value():.2f} tokens/decode-step")
print("batched output == sequential greedy decode for request 0")
print("serve_batch OK")
